//! Edge-deployment scenario (paper Table 4 / §4.2 "Smaller-Size LLM for
//! Edge Inference"): quantize the nano model to W2/W3/W4 with TesseraQ,
//! pack the weights, and report the memory/accuracy/latency trade-off a
//! deployment engineer would look at.
//!
//!   cargo run --release --example edge_deploy

use tesseraq::data::CorpusKind;
use tesseraq::eval::Evaluator;
use tesseraq::experiments::methods::{quantize, Method, MethodOpts};
use tesseraq::experiments::Ctx;
use tesseraq::quant::{GroupScheme, QuantConfig};
use tesseraq::report::fmt_bytes;
use tesseraq::serve::ServeModel;

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new(true)?;
    let size = "nano";
    let base = ctx.base_model(size, CorpusKind::WikiLike)?;
    let wiki = ctx.corpus(CorpusKind::WikiLike, size)?;
    let ev = Evaluator::new(&ctx.eng, size)?;

    let dense = ServeModel::dense(&base);
    let ppl_fp = ev.perplexity(&base, None, 65535.0, &wiki, 16, 3)?;
    println!("{:<6} {:<10} {:>8} {:>10} {:>10}", "bits", "ppl", "WM", "tok/s b1", "tok/s b4");
    let bench = |m: &ServeModel| -> anyhow::Result<(f64, f64)> {
        let p1 = vec![wiki.sample(12, 0)];
        let (_, s1) = m.generate(&p1, 32)?;
        let p4: Vec<Vec<i32>> = (0..4).map(|i| wiki.sample(12, i as u64)).collect();
        let (_, s4) = m.generate(&p4, 32)?;
        Ok((s1.tokens_per_s, s4.tokens_per_s))
    };
    let (t1, t4) = bench(&dense)?;
    println!("{:<6} {:<10.3} {:>8} {:>10.1} {:>10.1}", "fp16", ppl_fp,
             fmt_bytes(dense.weight_bytes()), t1, t4);

    for bits in [4u32, 3, 2] {
        let qcfg = QuantConfig::weight_only(bits, GroupScheme::Group(32));
        let opts = MethodOpts::new(qcfg, 16, true);
        let q = quantize(&ctx.eng, &base, Method::TesseraQ, &qcfg, &wiki, &opts)?;
        let ppl = ev.perplexity(&q.params, None, 65535.0, &wiki, 16, 3)?;
        let report = q.report.as_ref().expect("TesseraQ report");
        let packed = ServeModel::packed(&q.params, report, bits)?;
        let (t1, t4) = bench(&packed)?;
        println!("{:<6} {:<10.3} {:>8} {:>10.1} {:>10.1}", format!("w{bits}"), ppl,
                 fmt_bytes(packed.weight_bytes()), t1, t4);
    }
    Ok(())
}
