//! Weight-activation quantization with QuaRot-style rotation (paper
//! Table 3): shows why W4A4 needs outlier suppression — plain RTN
//! collapses, rotation + GPTQ/TesseraQ recovers.
//!
//!   cargo run --release --example wa_quant_rotation

use tesseraq::data::CorpusKind;
use tesseraq::eval::Evaluator;
use tesseraq::experiments::methods::{quantize, Method, MethodOpts};
use tesseraq::experiments::Ctx;
use tesseraq::quant::{GroupScheme, QuantConfig};

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new(true)?;
    let size = "nano";
    let base = ctx.base_model(size, CorpusKind::WikiLike)?;
    let wiki = ctx.corpus(CorpusKind::WikiLike, size)?;
    let ev = Evaluator::new(&ctx.eng, size)?;
    let ppl_fp = ev.perplexity(&base, None, 65535.0, &wiki, 16, 11)?;
    println!("FP16 PPL {ppl_fp:.3}\n");
    println!("{:<16} {:>10}", "method", "W4A4 PPL");

    let qcfg = QuantConfig::new(4, GroupScheme::PerChannel, Some(4));
    for m in [
        Method::Rtn,
        Method::SmoothQuant,
        Method::QuaRot,
        Method::QuaRotGptq,
        Method::QuaRotTesseraQ,
    ] {
        let opts = MethodOpts::new(qcfg, 16, true);
        let q = quantize(&ctx.eng, &base, m, &qcfg, &wiki, &opts)?;
        let ppl = ev.perplexity(&q.params, q.head_t.as_ref(), qcfg.qmax_act(), &wiki, 16, 11)?;
        println!("{:<16} {:>10.3}", m.label(), ppl);
    }
    Ok(())
}
