//! Quickstart: the minimal TesseraQ flow on the nano model.
//!
//!   cargo run --release --example quickstart
//!
//! 1. pretrain a nano LM via the AOT train-step artifact
//! 2. quantize it to W2 with plain RTN and with TesseraQ
//! 3. compare wiki-like perplexity

use tesseraq::coordinator::par::{calibrate_tesseraq, TesseraqConfig};
use tesseraq::coordinator::pretrain::{pretrain, PretrainConfig};
use tesseraq::data::{Corpus, CorpusKind};
use tesseraq::eval::Evaluator;
use tesseraq::experiments::methods::rtn_model;
use tesseraq::model::{ModelConfig, Params};
use tesseraq::quant::{GroupScheme, QuantConfig};
use tesseraq::tensor::Pcg32;
use tesseraq::Engine;

fn main() -> anyhow::Result<()> {
    let eng = Engine::from_default_dir()?;
    println!("PJRT platform: {}", eng.platform());

    // 1. pretrain
    let cfg = ModelConfig::preset("nano")?;
    let corpus = Corpus::new(CorpusKind::WikiLike, cfg.vocab_size);
    let mut rng = Pcg32::seeded(42);
    let mut params = Params::init(&cfg, &mut rng);
    let pcfg = PretrainConfig { steps: 80, ..Default::default() };
    println!("pretraining nano ({:.2}M params)...", cfg.param_count() as f64 / 1e6);
    pretrain(&eng, &mut params, &corpus, &pcfg, |s, l| println!("  step {s:>3} loss {l:.4}"))?;

    // 2. evaluate FP, RTN, TesseraQ at W2A16g32
    let ev = Evaluator::new(&eng, "nano")?;
    let ppl_fp = ev.perplexity(&params, None, 65535.0, &corpus, 16, 7)?;

    let qcfg = QuantConfig::weight_only(2, GroupScheme::Group(32));
    let mut p_rtn = params.clone();
    rtn_model(&mut p_rtn, &qcfg);
    let ppl_rtn = ev.perplexity(&p_rtn, None, 65535.0, &corpus, 16, 7)?;

    let mut p_tq = params.clone();
    let tokens = corpus.sequences(16, cfg.max_seq, 123);
    let tcfg = TesseraqConfig::standard(qcfg);
    let report = calibrate_tesseraq(&eng, &mut p_tq, None, &tokens, 16, &tcfg)?;
    let ppl_tq = ev.perplexity(&p_tq, None, 65535.0, &corpus, 16, 7)?;

    println!("\n== W2A16g32 on nano ==");
    println!("FP16      PPL: {ppl_fp:.3}");
    println!("RTN       PPL: {ppl_rtn:.3}");
    println!("TesseraQ  PPL: {ppl_tq:.3}  (calibrated in {:.1}s)", report.wall_s);
    assert!(ppl_tq < ppl_rtn, "TesseraQ should beat RTN");
    Ok(())
}
