//! End-to-end validation driver (DESIGN.md §6): pretrain the tiny model
//! (several hundred AOT train steps, loss curve logged), quantize with
//! RTN / AWQ / TesseraQ at W2A16g64, evaluate perplexity + zero-shot
//! accuracy for each, then serve the packed INT2 model and report
//! weight-memory compression and tokens/s. Results are appended to
//! results/e2e.md; EXPERIMENTS.md records a captured run.
//!
//!   cargo run --release --example e2e_train_quant_eval [-- --fast]

use tesseraq::data::CorpusKind;
use tesseraq::eval::Evaluator;
use tesseraq::experiments::methods::{quantize, Method, MethodOpts};
use tesseraq::experiments::Ctx;
use tesseraq::quant::{GroupScheme, QuantConfig};
use tesseraq::report::{append_log, fmt_bytes};
use tesseraq::serve::ServeModel;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let ctx = Ctx::new(fast)?;
    let size = "tiny";
    println!("== E2E train->quantize->eval->serve ({size}, fast={fast}) ==");

    // 1. pretrain (cached; loss curve printed by base_model on first run)
    let t0 = std::time::Instant::now();
    let base = ctx.base_model(size, CorpusKind::WikiLike)?;
    println!("base model ready in {:.1}s", t0.elapsed().as_secs_f64());

    let wiki = ctx.corpus(CorpusKind::WikiLike, size)?;
    let ev = Evaluator::new(&ctx.eng, size)?;
    let ppl_fp = ev.perplexity(&base, None, 65535.0, &wiki, ctx.n_eval(), 0xE2E)?;
    let acc_fp = ev.zeroshot_suite(&base, None, 65535.0, &wiki, ctx.n_items(), 24)?;
    println!("FP16: PPL {ppl_fp:.3}, zero-shot avg {:.2}%",
             acc_fp.last().unwrap().1 * 100.0);

    let qcfg = QuantConfig::weight_only(2, GroupScheme::Group(64));
    let mut log = format!(
        "## e2e_train_quant_eval {size} {} (fast={fast})\n\n| method | PPL | acc avg | calib s |\n|---|---|---|---|\n| FP16 | {ppl_fp:.3} | {:.2} | - |\n",
        qcfg.label(),
        acc_fp.last().unwrap().1 * 100.0
    );

    let mut tq_report = None;
    let mut tq_params = None;
    for m in [Method::Rtn, Method::Awq, Method::TesseraQ] {
        let opts = MethodOpts::new(qcfg, ctx.n_calib(), ctx.fast);
        let t1 = std::time::Instant::now();
        let q = quantize(&ctx.eng, &base, m, &qcfg, &wiki, &opts)?;
        let dt = t1.elapsed().as_secs_f64();
        let ppl = ev.perplexity(&q.params, q.head_t.as_ref(), qcfg.qmax_act(), &wiki,
                                ctx.n_eval(), 0xE2E)?;
        let accs = ev.zeroshot_suite(&q.params, q.head_t.as_ref(), qcfg.qmax_act(),
                                     &wiki, ctx.n_items(), 24)?;
        let avg = accs.last().unwrap().1 * 100.0;
        println!("{:<10} PPL {ppl:8.3}  acc {avg:5.2}%  ({dt:.1}s)", m.label());
        log.push_str(&format!("| {} | {ppl:.3} | {avg:.2} | {dt:.1} |\n", m.label()));
        if m == Method::TesseraQ {
            tq_report = q.report;
            tq_params = Some(q.params);
        }
    }

    // 3. packed serving
    let report = tq_report.unwrap();
    let params = tq_params.unwrap();
    let dense = ServeModel::dense(&base);
    let packed = ServeModel::packed(&params, &report, qcfg.w_bits)?;
    for (label, model) in [("FP16 dense", &dense), ("W2 packed", &packed)] {
        let prompts: Vec<Vec<i32>> = (0..4).map(|i| wiki.sample(16, i as u64)).collect();
        let (_, stats) = model.generate(&prompts, if fast { 16 } else { 48 })?;
        println!("{label:<12} WM {:<9} {:.1} tok/s",
                 fmt_bytes(stats.weight_bytes), stats.tokens_per_s);
        log.push_str(&format!("\nserve {label}: WM {}, {:.1} tok/s",
                              fmt_bytes(stats.weight_bytes), stats.tokens_per_s));
    }
    append_log("e2e.md", &log)?;
    println!("\nrecorded to results/e2e.md");
    Ok(())
}
