//! Minimal, offline-compatible subset of the `anyhow` API.
//!
//! The build environment has no network access and no vendored crates.io
//! registry, so this in-tree crate supplies exactly the surface the
//! tesseraq codebase uses: `Error`, `Result`, `Context` (on both `Result`
//! and `Option`), and the `bail!` / `ensure!` / `anyhow!` macros.
//!
//! The error is a chain of human-readable messages, outermost first.
//! `{}` displays the outermost message, `{:#}` joins the chain with
//! `": "` (matching anyhow's alternate Display), and `{:?}` prints the
//! outermost message followed by a "Caused by:" list.

use std::fmt;

/// Error type: a context chain, outermost message first.
pub struct Error {
    chain: Vec<String>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow: Error deliberately does NOT implement std::error::Error,
// which is what makes the blanket From impl below coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the std source chain as context entries.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Attach context to fallible values (`Result` and `Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
    ($cond:expr) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e).context("reading file")
    }

    #[test]
    fn display_modes() {
        let err = io_fail().unwrap_err();
        assert_eq!(format!("{err}"), "reading file");
        assert_eq!(format!("{err:#}"), "reading file: gone");
        assert!(format!("{err:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        let err = v.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(err.to_string(), "missing 7");

        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(3).is_err());
    }

    #[test]
    fn question_mark_conversion() {
        fn g() -> Result<u32> {
            let n: u32 = "17".parse()?;
            Ok(n)
        }
        assert_eq!(g().unwrap(), 17);
    }
}
