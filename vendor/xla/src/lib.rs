//! PJRT/XLA API **stub**.
//!
//! The full environment vendors an `xla` crate backed by the PJRT CPU
//! plugin (see rust/src/runtime/mod.rs). This stub mirrors that crate's
//! type and method surface so the workspace builds and the host-side
//! paths (quantizer, packed serving, host-forward calibration fallback,
//! resilience layer) run everywhere; every device entry point returns a
//! clean `XlaError` instead of linking against PJRT.
//!
//! `Engine::new` therefore fails gracefully in stub builds, which is
//! exactly the "persistent artifact failure" regime the robust layer is
//! designed to survive: callers fall back to `model/hostfwd.rs`.
//! Artifact-gated integration tests detect the missing runtime and skip.

use std::borrow::Borrow;
use std::fmt;

#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla (stub): {}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what} requires the PJRT-backed xla crate; this build uses the in-tree stub"
    ))
}

pub struct PjRtClient(());
pub struct PjRtDevice(());
pub struct PjRtBuffer(());
pub struct PjRtLoadedExecutable(());
pub struct HloModuleProto(());
pub struct XlaComputation(());
pub struct Literal(());
pub struct ArrayShape(Vec<i64>);

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("buffer_from_host_buffer"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b<L: Borrow<PjRtBuffer>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute_b"))
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable("Literal::array_shape"))
    }

    pub fn to_vec<T: Default + Clone>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_are_descriptive() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("stub"));
    }
}
