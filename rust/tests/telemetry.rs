//! Integration tests for the structured telemetry layer (ISSUE
//! acceptance criteria): every emitted trace line must parse through
//! `util::json`, spans must nest with correct self-time accounting,
//! histogram buckets must sit on exact powers of two, and a kill@block
//! + `--resume` pair must produce ONE merged JSONL trace whose two
//! halves share the run fingerprint and together cover every block.
//!
//! The sink is process-global, so every test that arms it holds `LOCK`
//! and disarms before releasing (the cargo test harness runs tests on
//! parallel threads within this binary).

use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::Mutex;

use tesseraq::data::{Corpus, CorpusKind};
use tesseraq::experiments::methods::gptq_model;
use tesseraq::model::{ModelConfig, Params};
use tesseraq::obs;
use tesseraq::obs::summary::render_summary;
use tesseraq::obs::Histogram;
use tesseraq::quant::{GroupScheme, QuantConfig};
use tesseraq::robust::{FaultPlan, RobustConfig, KILL_MARKER};
use tesseraq::tensor::Pcg32;
use tesseraq::util::json::Json;

static LOCK: Mutex<()> = Mutex::new(());

const N_SEQ: usize = 2;

fn setup() -> (Params, Vec<i32>, QuantConfig) {
    let cfg = ModelConfig::preset("nano").expect("nano preset");
    let mut rng = Pcg32::seeded(0xB0B);
    let params = Params::init(&cfg, &mut rng);
    let corpus = Corpus::new(CorpusKind::WikiLike, cfg.vocab_size);
    let tokens = corpus.sequences(N_SEQ, cfg.max_seq, 0xCA11B);
    let qcfg = QuantConfig::weight_only(2, GroupScheme::Group(32));
    (params, tokens, qcfg)
}

fn test_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tesseraq_obs_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Read the trace, asserting the line-level schema: every non-empty line
/// parses as one JSON object with `seq` (strictly increasing within a
/// process run), `ts_ms`, and `kind`.
fn read_trace(dir: &Path) -> Vec<Json> {
    let text = std::fs::read_to_string(dir.join("trace.jsonl")).expect("trace.jsonl");
    let mut events = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line).unwrap_or_else(|e| panic!("line {}: {e:#}\n{line}", i + 1));
        j.get("seq").and_then(|v| v.as_f64()).expect("seq field");
        j.get("ts_ms").and_then(|v| v.as_f64()).expect("ts_ms field");
        j.get("kind").and_then(|v| v.as_str()).expect("kind field");
        events.push(j);
    }
    events
}

fn kind_of(j: &Json) -> String {
    j.get("kind").unwrap().as_str().unwrap().to_string()
}

fn f64_field(j: &Json, k: &str) -> f64 {
    j.get(k).and_then(|v| v.as_f64()).unwrap_or_else(|e| panic!("field {k}: {e:#}"))
}

#[test]
fn spans_nest_and_self_time_excludes_children() {
    let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let dir = test_dir("spans");
    obs::init(&dir).expect("init sink");

    {
        let _outer = tesseraq::span!("outer");
        std::thread::sleep(std::time::Duration::from_millis(15));
        {
            let _inner = tesseraq::span!("inner", 7);
            std::thread::sleep(std::time::Duration::from_millis(15));
        }
    }
    obs::hist_record("test.lat_ms", 3.0);
    obs::counter_add("test.events", 2);
    obs::shutdown(); // flushes metrics, disarms

    let events = read_trace(&dir);
    // seq strictly increasing within the single process run
    let seqs: Vec<f64> = events.iter().map(|j| f64_field(j, "seq")).collect();
    assert!(seqs.windows(2).all(|w| w[1] > w[0]), "seq not increasing: {seqs:?}");

    let opens: Vec<&Json> = events.iter().filter(|j| kind_of(j) == "span_open").collect();
    let closes: Vec<&Json> = events.iter().filter(|j| kind_of(j) == "span_close").collect();
    assert_eq!(opens.len(), 2);
    assert_eq!(closes.len(), 2);

    let outer_id = f64_field(opens[0], "id");
    let inner = opens[1];
    assert_eq!(inner.get("name").unwrap().as_str().unwrap(), "inner");
    assert_eq!(f64_field(inner, "parent"), outer_id, "inner span must link to outer");
    assert_eq!(inner.get("detail").unwrap().as_str().unwrap(), "7");

    // inner closes first (RAII); self == wall for a leaf
    let (c_inner, c_outer) = (closes[0], closes[1]);
    assert_eq!(c_inner.get("name").unwrap().as_str().unwrap(), "inner");
    assert_eq!(c_outer.get("name").unwrap().as_str().unwrap(), "outer");
    let (iw, is) = (f64_field(c_inner, "wall_ms"), f64_field(c_inner, "self_ms"));
    let (ow, os) = (f64_field(c_outer, "wall_ms"), f64_field(c_outer, "self_ms"));
    assert!((iw - is).abs() < 1e-6, "leaf self ({is}) must equal wall ({iw})");
    assert!(ow >= iw, "outer wall ({ow}) must cover inner ({iw})");
    // self = wall minus direct children, exactly (up to f64 rounding)
    assert!((os - (ow - iw)).abs() < 1e-3, "outer self {os} != wall {ow} - child {iw}");

    // shutdown flushed the registry: both metrics landed as events
    let metrics: Vec<&Json> = events.iter().filter(|j| kind_of(j) == "metric").collect();
    assert!(metrics.iter().any(|j| {
        j.get("metric").unwrap().as_str().unwrap() == "test.lat_ms"
            && f64_field(j, "count") == 1.0
    }));
    assert!(metrics.iter().any(|j| {
        j.get("metric").unwrap().as_str().unwrap() == "test.events"
            && f64_field(j, "value") == 2.0
    }));

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn histogram_buckets_sit_on_powers_of_two() {
    // mirrored from the unit tests, through the public re-export: the
    // trace-summary quantiles depend on these exact boundaries
    assert_eq!(Histogram::bucket_index(0.5), 0);
    assert_eq!(Histogram::bucket_index(1.0), 1);
    assert_eq!(Histogram::bucket_index(2.0), 2);
    assert_eq!(Histogram::bucket_index(4095.9), 12);
    assert_eq!(Histogram::bucket_index(4096.0), 13);
    assert_eq!(Histogram::bucket_bound(13), 8192.0);
    let mut h = Histogram::default();
    for v in [0.25, 1.5, 6.0, 6.5, 2000.0] {
        h.record(v);
    }
    assert_eq!(h.count, 5);
    assert_eq!(h.quantile(0.5), 8.0); // third sample is in [4, 8)
    assert!(h.quantile(0.5) <= h.quantile(0.95));
}

#[test]
fn kill_and_resume_merge_into_one_trace() {
    let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let (base, tokens, qcfg) = setup();
    let n_layers = base.cfg.n_layers;
    let dir = test_dir("resume");
    let trace = dir.join("trace");
    let ckpt = dir.join("ckpt");

    // first half: killed right after block 0's checkpoint is persisted
    obs::init(&trace).expect("init sink");
    let mut robust = RobustConfig::with_checkpoints(&ckpt, false);
    robust.faults = Some(Rc::new(FaultPlan::parse("kill@0").unwrap()));
    let mut p_killed = base.clone();
    let err = gptq_model(None, &mut p_killed, &tokens, N_SEQ, &qcfg, &robust)
        .expect_err("injected kill must abort the run");
    assert!(format!("{err:#}").contains(KILL_MARKER), "unexpected error: {err:#}");
    obs::shutdown();

    // second half: a fresh process arming the SAME trace dir must append
    obs::init(&trace).expect("re-init sink");
    let mut p_resumed = base.clone();
    let report = gptq_model(
        None,
        &mut p_resumed,
        &tokens,
        N_SEQ,
        &qcfg,
        &RobustConfig::with_checkpoints(&ckpt, true),
    )
    .expect("resumed run");
    obs::shutdown();
    assert_eq!(report.per_block.len(), n_layers);

    // ONE merged trace covering both halves
    let events = read_trace(&trace);
    let starts: Vec<&Json> = events.iter().filter(|j| kind_of(j) == "run_start").collect();
    assert_eq!(starts.len(), 2, "each half records a run_start");
    let fp0 = starts[0].get("fingerprint").unwrap().as_str().unwrap().to_string();
    let fp1 = starts[1].get("fingerprint").unwrap().as_str().unwrap().to_string();
    assert_eq!(fp0, fp1, "both halves must share the run fingerprint");
    assert!(!starts[0].get("resume").unwrap().as_f64().is_ok(), "resume is a bool field");

    for kind in [
        "telemetry_init",
        "fault_injected",
        "checkpoint_write",
        "checkpoint_load",
        "resume",
        "block_done",
        "span_open",
        "span_close",
        "run_end",
    ] {
        assert!(
            events.iter().any(|j| kind_of(j) == kind),
            "required event kind {kind:?} missing from merged trace"
        );
    }

    // the two halves together cover every block exactly once
    let mut done: Vec<u64> = events
        .iter()
        .filter(|j| kind_of(j) == "block_done")
        .map(|j| f64_field(j, "layer") as u64)
        .collect();
    done.sort_unstable();
    let want: Vec<u64> = (0..n_layers as u64).collect();
    assert_eq!(done, want, "block_done coverage across kill + resume");

    // manifest ties both halves to the same fingerprint
    let mtext = std::fs::read_to_string(trace.join("manifest.json")).expect("manifest.json");
    let manifest = Json::parse(&mtext).expect("manifest parses");
    let runs = manifest.get("runs").unwrap().as_arr().unwrap();
    assert_eq!(runs.len(), 2);
    for r in runs {
        assert_eq!(r.get("fingerprint").unwrap().as_str().unwrap(), fp0);
        assert_eq!(r.get("method").unwrap().as_str().unwrap(), "gptq");
    }

    // trace-summary renders the profile + loss table from the merged trace
    let s = render_summary(&trace).expect("render_summary");
    assert!(s.contains(&format!("fingerprint={fp0}")), "{s}");
    assert!(s.contains("Per-phase self-time profile"), "{s}");
    assert!(s.contains("Per-block reconstruction loss"), "{s}");
    for phase in ["block", "optimize", "propagate"] {
        assert!(s.contains(phase), "phase {phase:?} missing from summary:\n{s}");
    }

    // the CalibReport JSON artifact is valid util::json
    let rep_json = Json::parse(&report.to_json()).expect("CalibReport::to_json parses");
    assert_eq!(rep_json.get("per_block").unwrap().as_arr().unwrap().len(), n_layers);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn disabled_sink_stays_dark() {
    let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    assert!(!obs::enabled());
    // all entry points must be inert no-ops without an armed sink
    obs::event("noop", &[("k", 1usize.into())]);
    obs::warn("noop", "[test] disabled-path warn", &[]);
    obs::counter_add("noop", 1);
    obs::hist_record("noop", 1.0);
    obs::flush_metrics();
    let _sp = tesseraq::span!("noop");
    assert!(obs::trace_dir().is_none());
}
