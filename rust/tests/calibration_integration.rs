//! End-to-end calibration integration on the nano model: pretrain via the
//! AOT train-step artifact, quantize with RTN / TesseraQ, and check the
//! paper's core claims hold on this substrate:
//!   - PAR reduces block reconstruction loss (Fig. 4 shape)
//!   - TesseraQ PPL beats RTN PPL at 2 bits (Tables 1/4 shape)
//!   - some but not all rounding variables flip (Table 7 shape)
//!   - the host forward matches the block_fp_fwd artifact (contract test)

use tesseraq::coordinator::par::{calibrate_tesseraq, TesseraqConfig};
use tesseraq::coordinator::pipeline::BlockRunner;
use tesseraq::coordinator::pretrain::{pretrain, PretrainConfig};
use tesseraq::data::{Corpus, CorpusKind};
use tesseraq::eval::Evaluator;
use tesseraq::model::hostfwd::{block_fwd, BlockFwdOpts};
use tesseraq::model::{ModelConfig, Params};
use tesseraq::quant::{self, GroupScheme, QuantConfig};
use tesseraq::runtime::Engine;
use tesseraq::tensor::{Pcg32, Tensor};

fn engine() -> Option<Engine> {
    let dir = tesseraq::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return None;
    }
    Some(Engine::new(dir).expect("engine"))
}

fn trained_nano(eng: &Engine, corpus: &Corpus) -> Params {
    let cfg = ModelConfig::preset("nano").unwrap();
    let mut rng = Pcg32::seeded(7);
    let mut params = Params::init(&cfg, &mut rng);
    let pcfg = PretrainConfig { steps: 60, lr: 4e-3, lr_min: 1e-3, seed: 0, log_every: 1000 };
    let rep = pretrain(eng, &mut params, corpus, &pcfg, |_, _| {}).expect("pretrain");
    assert!(
        rep.losses.last().unwrap() + 0.3 < rep.losses[0],
        "pretraining did not learn: {:?} -> {:?}",
        rep.losses[0],
        rep.losses.last().unwrap()
    );
    params
}

#[test]
fn host_forward_matches_artifact() {
    let Some(eng) = engine() else { return };
    let cfg = ModelConfig::preset("nano").unwrap();
    let mut rng = Pcg32::seeded(3);
    let params = Params::init(&cfg, &mut rng);
    let runner = BlockRunner::new(&eng, "nano").expect("runner");
    let x = Tensor::randn(&[runner.batch, cfg.max_seq, cfg.d_model], 1.0, &mut rng);
    let bw = params.block(0);
    let y_art = runner.forward_batch(&bw, &x, quant::A16_SENTINEL).expect("artifact fwd");
    let (y_host, _) = block_fwd(&x, &bw, &cfg, &BlockFwdOpts::default());
    let rmse = y_art.mse(&y_host).sqrt();
    let scale = y_art.abs_max();
    assert!(
        rmse < 1e-3 * scale.max(1.0) as f64,
        "host/artifact forward diverged: rmse {rmse}, scale {scale}"
    );
}

#[test]
fn tesseraq_beats_rtn_at_2bit() {
    let Some(eng) = engine() else { return };
    let corpus = Corpus::new(CorpusKind::WikiLike, 128);
    let params_fp = trained_nano(&eng, &corpus);
    let ev = Evaluator::new(&eng, "nano").expect("eval");
    let ppl_fp = ev
        .perplexity(&params_fp, None, quant::A16_SENTINEL, &corpus, 16, 999)
        .expect("ppl fp");

    let qcfg = QuantConfig::weight_only(2, GroupScheme::Group(32));
    let qmax = qcfg.qmax_w();

    // RTN baseline
    let mut p_rtn = params_fp.clone();
    for l in 0..p_rtn.cfg.n_layers {
        let bw = p_rtn.block(l);
        for (name, w) in &bw.linears {
            let g = qcfg.scheme.group_size(w.shape[1]);
            let qp = quant::minmax_scale(
                w,
                g,
                &quant::ClipFactors::Uniform(1.0),
                &quant::ClipFactors::Uniform(1.0),
                qmax,
            );
            let wq = quant::rtn_qdq(w, &qp, qmax);
            p_rtn.set_block_linear(l, name, &wq);
        }
    }
    let ppl_rtn = ev
        .perplexity(&p_rtn, None, quant::A16_SENTINEL, &corpus, 16, 999)
        .expect("ppl rtn");

    // TesseraQ
    let mut p_tq = params_fp.clone();
    let n_seq = 16;
    let tokens = corpus.sequences(n_seq, p_tq.cfg.max_seq, 12345);
    let mut tcfg = TesseraqConfig::fast(qcfg);
    tcfg.iterations = 6;
    tcfg.steps_per_iter = 16;
    let report =
        calibrate_tesseraq(&eng, &mut p_tq, None, &tokens, n_seq, &tcfg).expect("tesseraq");
    let ppl_tq = ev
        .perplexity(&p_tq, None, quant::A16_SENTINEL, &corpus, 16, 999)
        .expect("ppl tq");

    eprintln!("PPL fp={ppl_fp:.3} rtn={ppl_rtn:.3} tesseraq={ppl_tq:.3}");
    assert!(ppl_rtn > ppl_fp, "2-bit RTN should damage PPL");
    assert!(
        ppl_tq < ppl_rtn * 0.995,
        "TesseraQ ({ppl_tq:.3}) must beat RTN ({ppl_rtn:.3})"
    );

    // Fig. 4 shape: hardening raises the loss (discreteness is forced in)
    // and the final soften/DST phase must not diverge — the loss at the
    // end of the last iteration stays at or below the loss right after
    // the last harden event.
    let spi = tcfg.steps_per_iter;
    for trace in &report.per_block {
        let last_iter_start = trace.losses[(tcfg.iterations - 1) * spi];
        let last = *trace.losses.last().unwrap();
        assert!(
            last <= last_iter_start * 1.10 + 1e-6,
            "block {} diverged in final iteration: {last_iter_start} -> {last}",
            trace.layer
        );
        assert!(trace.losses.iter().all(|l| l.is_finite()));
    }

    // Table 7 shape: some (but not all) rounding variables flip
    let mut total_flips = 0usize;
    let mut total_vars = 0usize;
    for trace in &report.per_block {
        for (flips, total) in trace.flips.values() {
            total_flips += flips;
            total_vars += total;
        }
    }
    let pct = total_flips as f64 / total_vars as f64;
    eprintln!("flipped {total_flips}/{total_vars} ({:.2}%)", pct * 100.0);
    assert!(pct > 0.001, "PAR flipped nothing");
    assert!(pct < 0.5, "PAR flipped half the weights — broken");
}

#[test]
fn dst_only_and_par_only_both_run() {
    // Table 6 machinery: each ablation combination runs and produces
    // finite, decreasing-or-flat losses (full numbers in `repro table 6`).
    let Some(eng) = engine() else { return };
    let corpus = Corpus::new(CorpusKind::WikiLike, 128);
    let params_fp = trained_nano(&eng, &corpus);
    let qcfg = QuantConfig::weight_only(2, GroupScheme::Group(32));
    let n_seq = 8;
    let tokens = corpus.sequences(n_seq, params_fp.cfg.max_seq, 777);

    let run = |par: bool, dst: bool| -> f32 {
        let mut p = params_fp.clone();
        let tcfg = TesseraqConfig {
            enable_par: par,
            enable_dst: dst,
            ..TesseraqConfig::fast(qcfg)
        };
        let rep = calibrate_tesseraq(&eng, &mut p, None, &tokens, n_seq, &tcfg).unwrap();
        *rep.per_block.last().unwrap().losses.last().unwrap()
    };

    let both = run(true, true);
    let par_only = run(true, false);
    let dst_only = run(false, true);
    eprintln!("final-block loss: both={both:.6} par={par_only:.6} dst={dst_only:.6}");
    assert!(both.is_finite() && par_only.is_finite() && dst_only.is_finite());
    // joint config should not be much worse than PAR alone
    assert!(both <= par_only * 1.5 + 1e-6);
}
