//! Integration smoke test: the Rust runtime loads, compiles and executes
//! real AOT artifacts (nano model), and the numerics round-trip.
//!
//! Requires `make artifacts` (skips with a message otherwise).

use tesseraq::runtime::{Arg, Engine};
use tesseraq::tensor::{Pcg32, Tensor};

fn engine() -> Option<Engine> {
    let dir = tesseraq::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {}", dir.display());
        return None;
    }
    Some(Engine::new(dir).expect("engine"))
}

#[test]
fn nano_block_fp_fwd_runs_and_is_causal_free() {
    let Some(eng) = engine() else { return };
    let art = eng.artifact("block_fp_fwd.nano").expect("artifact");
    let spec = art.spec.clone();
    let mut rng = Pcg32::seeded(0);
    let mut args: Vec<Tensor> = Vec::new();
    for io in &spec.inputs {
        let std = if io.name.starts_with("norm") { 0.0 } else { 0.05 };
        let mut t = Tensor::randn(&io.shape, std, &mut rng);
        if io.name.starts_with("norm") {
            t = Tensor::full(&io.shape, 1.0);
        }
        args.push(t);
    }
    // qmax_act = A16 sentinel
    let n = args.len();
    args[n - 1] = Tensor::scalar(65535.0);
    let argrefs: Vec<Arg> = args.iter().map(Arg::F32).collect();
    let outs = eng.run(&art, &argrefs).expect("run");
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].shape, spec.inputs[0].shape);
    assert!(outs[0].data.iter().all(|v| v.is_finite()));
    // determinism
    let outs2 = eng.run(&art, &argrefs).expect("run2");
    assert_eq!(outs[0].data, outs2[0].data);
}

#[test]
fn nano_model_nll_shape_and_range() {
    let Some(eng) = engine() else { return };
    let art = eng.artifact("model_fwd_nll.nano").expect("artifact");
    let spec = art.spec.clone();
    let mut rng = Pcg32::seeded(1);
    let tok_shape = spec.inputs[0].shape.clone();
    let vocab = spec.meta.model.vocab_size;
    let tokens: Vec<i32> = (0..tok_shape.iter().product::<usize>())
        .map(|_| rng.below(vocab) as i32)
        .collect();
    let mut params: Vec<Tensor> = Vec::new();
    for io in &spec.inputs[1..spec.inputs.len() - 2] {
        if io.name.contains("norm") {
            params.push(Tensor::full(&io.shape, 1.0));
        } else {
            let fanin = *io.shape.last().unwrap() as f32;
            params.push(Tensor::randn(&io.shape, 0.4 / fanin.sqrt(), &mut rng));
        }
    }
    let d = spec.meta.model.d_model;
    let head_t = tesseraq::model::transform::identity_head_t(d);
    let mut args: Vec<Arg> = vec![Arg::I32(&tokens, &tok_shape)];
    args.extend(params.iter().map(Arg::F32));
    args.push(Arg::F32(&head_t));
    args.push(Arg::Scalar(65535.0));
    let outs = eng.run(&art, &args).expect("run");
    let nll = &outs[0];
    assert_eq!(nll.shape, vec![tok_shape[0], tok_shape[1] - 1]);
    // untrained random model: mean NLL ~ ln(vocab)
    let mean = nll.mean();
    let expect = (vocab as f64).ln();
    assert!(
        (mean - expect).abs() < 1.0,
        "mean NLL {mean} vs ln(V) {expect}"
    );
}

#[test]
fn arg_shape_validation_rejects_mismatch() {
    let Some(eng) = engine() else { return };
    let art = eng.artifact("block_fp_fwd.nano").expect("artifact");
    let bad = Tensor::zeros(&[1, 2, 3]);
    let args: Vec<Arg> = art.spec.inputs.iter().map(|_| Arg::F32(&bad)).collect();
    assert!(eng.run(&art, &args).is_err());
}

#[test]
fn qmatmul_artifact_matches_host_dequant() {
    let Some(eng) = engine() else { return };
    let art = eng.artifact("qmatmul_w4.nano").expect("artifact");
    let spec = art.spec.clone();
    let mut rng = Pcg32::seeded(2);
    let xs = &spec.inputs[0].shape;
    let ps = &spec.inputs[1].shape;
    let ss = &spec.inputs[2].shape;
    let (m, k) = (xs[0], xs[1]);
    let o = ps[0];
    let g = k / ss[1];
    let bits = 4u32;
    let per = 32 / bits as usize;
    let x = Tensor::randn(xs, 1.0, &mut rng);
    let codes: Vec<u32> = (0..o * k).map(|_| rng.below(16) as u32).collect();
    let mut packed = vec![0i32; o * ps[1]];
    for r in 0..o {
        for j in 0..k {
            let w = r * ps[1] + j / per;
            packed[w] =
                (packed[w] as u32 | (codes[r * k + j] << (bits as usize * (j % per)))) as i32;
        }
    }
    let s = Tensor::from_fn(ss, |_| 0.01 + rng.uniform() as f32 * 0.3);
    let z = Tensor::from_fn(ss, |_| rng.below(16) as f32);
    let args = vec![
        Arg::F32(&x),
        Arg::I32(&packed, ps),
        Arg::F32(&s),
        Arg::F32(&z),
    ];
    let y = eng.run(&art, &args).expect("run");
    // host dequant reference
    let mut w = vec![0.0f32; o * k];
    for r in 0..o {
        for j in 0..k {
            let gidx = j / g;
            w[r * k + j] =
                s.data[r * ss[1] + gidx] * (codes[r * k + j] as f32 - z.data[r * ss[1] + gidx]);
        }
    }
    let wt = Tensor::new(vec![o, k], w);
    let want = wt.matmul_bt(&x);
    assert_eq!(y[0].shape, vec![m, o]);
    let err = y[0].mse(&want).sqrt();
    assert!(err < 1e-3, "rmse {err}");
}
