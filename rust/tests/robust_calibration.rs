//! Resilience integration tests (ISSUE acceptance criteria):
//!
//! * a calibration run killed mid-run via fault injection, then resumed
//!   from its checkpoints, produces a bit-identical CalibReport;
//! * persistent artifact failures degrade to the host-side reference
//!   forward and the run completes without panicking.
//!
//! Everything here drives `calibrate_tesseraq_robust` on the host path
//! (`eng = None`) so the tests are device-independent; when a PJRT device
//! and artifacts are present, the fallback test also exercises the real
//! engine with injected compile/exec failures.

use std::path::PathBuf;
use std::rc::Rc;

use tesseraq::coordinator::{calibrate_tesseraq_robust, BlockStatus, TesseraqConfig};
use tesseraq::data::{Corpus, CorpusKind};
use tesseraq::model::{ModelConfig, Params};
use tesseraq::quant::{GroupScheme, QuantConfig};
use tesseraq::robust::{FaultPlan, RobustConfig, KILL_MARKER};
use tesseraq::tensor::Pcg32;
use tesseraq::Engine;

const N_SEQ: usize = 2;

fn setup() -> (Params, Vec<i32>, TesseraqConfig) {
    let cfg = ModelConfig::preset("nano").expect("nano preset");
    let mut rng = Pcg32::seeded(0xB0B);
    let params = Params::init(&cfg, &mut rng);
    let corpus = Corpus::new(CorpusKind::WikiLike, cfg.vocab_size);
    let tokens = corpus.sequences(N_SEQ, cfg.max_seq, 0xCA11B);
    let qcfg = QuantConfig::weight_only(2, GroupScheme::Group(32));
    (params, tokens, TesseraqConfig::fast(qcfg))
}

fn test_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("tesseraq_robust_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn killed_run_resumes_bit_identical() {
    let (base, tokens, tcfg) = setup();
    let dir = test_dir("resume");

    // uninterrupted reference run
    let mut p_ref = base.clone();
    let report_ref = calibrate_tesseraq_robust(
        None, &mut p_ref, None, &tokens, N_SEQ, &tcfg, &RobustConfig::default(),
    )
    .expect("reference run");
    assert_eq!(report_ref.per_block.len(), base.cfg.n_layers);

    // same run, killed right after block 0's checkpoint is persisted
    let mut robust = RobustConfig::with_checkpoints(&dir, false);
    robust.faults = Some(Rc::new(FaultPlan::parse("kill@0").unwrap()));
    let mut p_killed = base.clone();
    let err = calibrate_tesseraq_robust(
        None, &mut p_killed, None, &tokens, N_SEQ, &tcfg, &robust,
    )
    .expect_err("injected kill must abort the run");
    assert!(
        format!("{err:#}").contains(KILL_MARKER),
        "unexpected error: {err:#}"
    );

    // resume from the surviving checkpoints
    let mut p_resumed = base.clone();
    let report_resumed = calibrate_tesseraq_robust(
        None,
        &mut p_resumed,
        None,
        &tokens,
        N_SEQ,
        &tcfg,
        &RobustConfig::with_checkpoints(&dir, true),
    )
    .expect("resumed run");

    // bit-identical report: codes, scales, and traces
    assert_eq!(report_resumed.quantized, report_ref.quantized);
    assert_eq!(report_resumed.per_block, report_ref.per_block);
    // and the merged model weights match bit for bit
    for name in tesseraq::model::PARAM_NAMES {
        assert_eq!(
            p_resumed.get(name).data,
            p_ref.get(name).data,
            "param {name} diverged after resume"
        );
    }

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_with_changed_config_restarts_clean() {
    let (base, tokens, tcfg) = setup();
    let dir = test_dir("fingerprint");

    // produce checkpoints under one config
    let mut robust = RobustConfig::with_checkpoints(&dir, false);
    robust.faults = Some(Rc::new(FaultPlan::parse("kill@0").unwrap()));
    let mut p = base.clone();
    let _ = calibrate_tesseraq_robust(None, &mut p, None, &tokens, N_SEQ, &tcfg, &robust)
        .expect_err("injected kill");

    // resume under a different quant config: the fingerprint mismatch must
    // refuse the stale prefix and the run completes from scratch
    let mut tcfg2 = tcfg.clone();
    tcfg2.qcfg = QuantConfig::weight_only(3, GroupScheme::Group(32));
    let mut p2 = base.clone();
    let report2 = calibrate_tesseraq_robust(
        None,
        &mut p2,
        None,
        &tokens,
        N_SEQ,
        &tcfg2,
        &RobustConfig::with_checkpoints(&dir, true),
    )
    .expect("restarted run");
    assert_eq!(report2.per_block.len(), base.cfg.n_layers);

    // and matches a fresh reference under the new config
    let mut p_ref = base.clone();
    let report_ref = calibrate_tesseraq_robust(
        None, &mut p_ref, None, &tokens, N_SEQ, &tcfg2, &RobustConfig::default(),
    )
    .expect("reference run");
    assert_eq!(report2.quantized, report_ref.quantized);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn persistent_artifact_failure_completes_via_host_fallback() {
    let (base, tokens, tcfg) = setup();

    match Engine::from_default_dir() {
        Ok(eng) => {
            // real device available: inject persistent compile+exec
            // failures for every block artifact; the run must still finish
            // on the host-forward path with every block degraded to RTN
            let mut robust = RobustConfig::default();
            robust.faults =
                Some(Rc::new(FaultPlan::parse("compile@block,exec@block").unwrap()));
            let mut p = base.clone();
            let report = calibrate_tesseraq_robust(
                Some(&eng), &mut p, None, &tokens, N_SEQ, &tcfg, &robust,
            )
            .expect("run must survive persistent artifact failures");
            assert_eq!(report.fallback_blocks().len(), base.cfg.n_layers);
        }
        Err(_) => {
            // no device in this environment: eng = None is exactly the
            // persistent-failure limit — every block completes as RTN
            let mut p = base.clone();
            let report = calibrate_tesseraq_robust(
                None, &mut p, None, &tokens, N_SEQ, &tcfg, &RobustConfig::default(),
            )
            .expect("host-only run");
            assert_eq!(report.per_block.len(), base.cfg.n_layers);
            for tr in &report.per_block {
                assert_eq!(tr.status, BlockStatus::RtnFallback);
            }
            assert!(!report.quantized.iter().any(|b| b.is_empty()));
        }
    }
}
