//! Serving-gateway chaos drills: request conservation under injected
//! faults. The invariant every drill checks — under poisoned-logits,
//! slow-step, queue-stall, and kill faults, every admitted request
//! terminates in EXACTLY ONE of {completed, deadline-missed,
//! failed-typed}, the KV slot ledger returns to zero (nothing leaks),
//! and every non-degraded completion is bit-identical to that prompt's
//! solo run (faults against one request never perturb another).
//!
//! `chaos_drill_from_env` additionally honours `TESSERAQ_FAULTS`, so the
//! CI `gateway-chaos` matrix reruns it under each fault spec; without
//! the env var it runs a combined default spec. No compiled artifacts
//! needed.

use std::rc::Rc;

use tesseraq::model::{ModelConfig, Params};
use tesseraq::robust::FaultPlan;
use tesseraq::serve::{
    Gateway, GatewayConfig, Request, RequestOutcome, ServeError, ServeModel,
};
use tesseraq::tensor::Pcg32;

fn nano_model(seed: u64) -> (ModelConfig, Params) {
    let cfg = ModelConfig::preset("nano").unwrap();
    let mut rng = Pcg32::seeded(seed);
    let p = Params::init(&cfg, &mut rng);
    (cfg, p)
}

/// The drill workload: a mix of prompt lengths and deadlines. Deadlines
/// are huge relative to real decode time (minutes) but tiny relative to
/// synthetic fault delays (hours), so outcomes depend on the fault spec,
/// never on machine speed.
fn workload() -> Vec<(Vec<i32>, usize, Option<u64>)> {
    vec![
        (vec![3, 17, 40, 9], 4, None),
        (vec![12, 7], 3, Some(120_000)),
        (vec![1, 2, 3, 4, 5], 5, None),
        (vec![60, 61, 62], 4, Some(120_000)),
        (vec![9, 9, 9, 9], 2, None),
        (vec![33, 44], 6, Some(120_000)),
    ]
}

/// Run the workload through a gateway armed with `plan` and check the
/// conservation invariant. Returns the terminal counters for
/// spec-specific assertions.
fn run_drill(
    m: &ServeModel,
    solo_ref: &ServeModel,
    plan: Rc<FaultPlan>,
) -> tesseraq::serve::GatewayCounters {
    let cfg = GatewayConfig {
        queue_depth: 16,
        max_batch: 2,
        kv_slot_budget: 512,
        breaker_threshold: 3,
        ..Default::default()
    };
    let mut gw = Gateway::new(m, cfg).with_faults(plan);
    let reqs = workload();
    let ids: Vec<u64> = reqs
        .iter()
        .map(|(p, n, dl)| {
            let mut r = Request::new(p.clone(), *n);
            if let Some(ms) = dl {
                r = r.with_deadline(*ms);
            }
            gw.submit(r).unwrap()
        })
        .collect();
    gw.drain();
    assert!(gw.idle(), "drain left work behind");

    // conservation: every admitted request has exactly one terminal
    // outcome, and the counter partition adds up
    let c = gw.counters().clone();
    assert_eq!(c.admitted, ids.len() as u64);
    assert_eq!(
        c.admitted,
        c.completed + c.deadline_missed + c.failed,
        "outcome partition does not cover admissions"
    );
    assert_eq!(gw.outcomes().len() as u64, c.admitted, "outcome per admitted request");
    // no KV slots leak: accounting returns to zero after the drain
    assert_eq!(gw.kv_in_use(), 0, "leaked KV slot reservations");
    assert!(gw.kv_peak() > 0, "drill never reserved anything");

    for (id, (prompt, new, _)) in ids.iter().zip(&reqs) {
        match &gw.outcomes()[id] {
            // unaffected rows: bit-identical to the solo run on the same
            // (primary) path
            RequestOutcome::Completed { tokens, degraded: false, .. } => {
                let (solo, _) = m.generate(std::slice::from_ref(prompt), *new).unwrap();
                assert_eq!(tokens, &solo[0], "request {id} diverged from solo");
            }
            // degraded rows: bit-identical to the dense fallback's solo run
            RequestOutcome::Completed { tokens, degraded: true, .. } => {
                let (solo, _) =
                    solo_ref.generate(std::slice::from_ref(prompt), *new).unwrap();
                assert_eq!(tokens, &solo[0], "degraded request {id} diverged from dense solo");
            }
            RequestOutcome::DeadlineMissed { .. } => {}
            // failed is always *typed* — the enum makes anything else
            // unrepresentable; pin the variants we expect from faults
            RequestOutcome::Failed(e) => assert!(
                matches!(
                    e,
                    ServeError::PoisonedLogits { .. }
                        | ServeError::SessionAborted
                        | ServeError::FallbackFailed(_)
                        | ServeError::KvCapacity { .. }
                ),
                "unexpected failure type: {e:?}"
            ),
        }
    }
    c
}

#[test]
fn chaos_drill_poison_slow_kill_combined() {
    // all three request-level fault kinds in one run: request 2 poisons
    // at its step 2, global decode step 4 takes 10^7 ms (evicting every
    // deadlined in-flight request), and the session is killed at global
    // step 6 (requeueing its rows once)
    let (_, p) = nano_model(30);
    let m = ServeModel::dense(&p);
    let plan = Rc::new(FaultPlan::parse("poison@2.2,slow@4.10000000,kill@6").unwrap());
    let c = run_drill(&m, &m, plan);
    assert!(c.failed >= 1, "poison without fallback must fail a request");
    assert!(c.deadline_missed >= 1, "synthetic slow step must evict a deadlined request");
    assert!(c.completed >= 1, "unaffected requests must still complete");
}

#[test]
fn chaos_drill_queue_stall() {
    // a stall before the first dispatch ages the whole queue past every
    // finite deadline: deadlined requests miss in-queue, undeadlined ones
    // complete untouched
    let (_, p) = nano_model(31);
    let m = ServeModel::dense(&p);
    let plan = Rc::new(FaultPlan::parse("stall@1.10000000").unwrap());
    let c = run_drill(&m, &m, plan);
    assert_eq!(c.deadline_missed, 3, "every deadlined request must expire in queue");
    assert_eq!(c.completed, 3, "every undeadlined request must complete");
    assert_eq!(c.failed, 0);
}

#[test]
fn chaos_drill_from_env() {
    // CI matrix entry point: rerun the conservation drill under whatever
    // TESSERAQ_FAULTS says; default to a kill+poison combination so the
    // test also bites locally
    let (_, p) = nano_model(32);
    let m = ServeModel::dense(&p);
    let plan = FaultPlan::from_env()
        .unwrap_or_else(|| Rc::new(FaultPlan::parse("kill@3,poison@4.1").unwrap()));
    run_drill(&m, &m, plan);
}

#[test]
fn degraded_fallback_completions_match_dense_solo() {
    // packed primary + dense fallback under repeated poison faults: the
    // breaker trips, poisoned requests complete degraded on the dense
    // path, and their outputs equal the dense model's solo runs exactly
    let (_, p) = nano_model(33);
    let packed = ServeModel::packed_rtn(&p, 2).unwrap();
    let dense = ServeModel::dense(&p);
    let cfg = GatewayConfig {
        queue_depth: 16,
        max_batch: 2,
        kv_slot_budget: 512,
        breaker_threshold: 2,
        ..Default::default()
    };
    let plan = Rc::new(FaultPlan::parse("poison@0.1,poison@1.1").unwrap());
    let mut gw = Gateway::new(&packed, cfg).with_fallback(&dense).with_faults(plan);
    let reqs = workload();
    let ids: Vec<u64> = reqs
        .iter()
        .map(|(p, n, _)| gw.submit(Request::new(p.clone(), *n)).unwrap())
        .collect();
    gw.drain();
    let c = gw.counters().clone();
    assert_eq!(c.admitted, c.completed + c.deadline_missed + c.failed);
    assert_eq!(gw.kv_in_use(), 0);
    assert!(gw.is_degraded(), "two consecutive packed poisons must trip the breaker");
    assert!(c.degraded >= 2, "poisoned requests must complete via the fallback");
    for (id, (prompt, new, _)) in ids.iter().zip(&reqs) {
        match &gw.outcomes()[id] {
            RequestOutcome::Completed { tokens, degraded, .. } => {
                let solo_model = if *degraded { &dense } else { &packed };
                let (solo, _) =
                    solo_model.generate(std::slice::from_ref(prompt), *new).unwrap();
                assert_eq!(tokens, &solo[0], "request {id} (degraded={degraded}) diverged");
            }
            other => panic!("request {id}: expected completion, got {other:?}"),
        }
    }
}

#[test]
fn overload_sheds_instead_of_collapsing() {
    // open-loop burst far past queue capacity: the gateway sheds with
    // typed reasons, serves exactly what it admitted, and conserves
    // every admitted request
    let (cfg_m, p) = nano_model(34);
    let m = ServeModel::dense(&p);
    let cfg = GatewayConfig {
        queue_depth: 4,
        max_batch: 2,
        kv_slot_budget: 128,
        ..Default::default()
    };
    let mut gw = Gateway::new(&m, cfg);
    let mut rng = Pcg32::seeded(99);
    let mut admitted = 0u64;
    let mut shed = 0u64;
    for _ in 0..32 {
        let len = 1 + rng.below(6);
        let prompt: Vec<i32> =
            (0..len).map(|_| rng.below(cfg_m.vocab_size) as i32).collect();
        match gw.submit(Request::new(prompt, 4)) {
            Ok(_) => admitted += 1,
            Err(reason) => {
                shed += 1;
                assert!(!reason.tag().is_empty());
            }
        }
    }
    assert!(shed > 0, "a 32-request burst into a depth-4 queue must shed");
    gw.drain();
    let c = gw.counters();
    assert_eq!(c.admitted, admitted);
    assert_eq!(c.shed, shed);
    assert_eq!(c.admitted, c.completed + c.deadline_missed + c.failed);
    assert_eq!(gw.kv_in_use(), 0);
}
