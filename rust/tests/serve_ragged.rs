//! Ragged-batch serving regressions. The old serve path padded short
//! prompts by re-feeding their last token during prefill, so a row's KV
//! cache (and therefore its output) depended on its batchmates. These
//! tests pin the contract: every row of a ragged batch generates exactly
//! the tokens it generates when served solo, for dense and packed models,
//! under both prefill strategies. No compiled artifacts needed.

use tesseraq::model::{ModelConfig, Params};
use tesseraq::serve::{PrefillMode, ServeModel};
use tesseraq::tensor::Pcg32;

fn nano_model(seed: u64) -> (ModelConfig, Params) {
    let cfg = ModelConfig::preset("nano").unwrap();
    let mut rng = Pcg32::seeded(seed);
    let p = Params::init(&cfg, &mut rng);
    (cfg, p)
}

fn solo_rows(m: &ServeModel, prompts: &[Vec<i32>], new: usize) -> Vec<Vec<i32>> {
    prompts
        .iter()
        .map(|p| {
            let (mut outs, _) = m.generate(std::slice::from_ref(p), new).unwrap();
            outs.remove(0)
        })
        .collect()
}

#[test]
fn ragged_batch_is_independent_of_batchmates_dense() {
    let (_, p) = nano_model(11);
    let m = ServeModel::dense(&p);
    let prompts = vec![
        vec![3i32, 17, 40, 9, 22, 5, 61, 30],
        vec![12i32, 7, 44],
        vec![1i32, 2, 3, 4, 5],
    ];
    let solo = solo_rows(&m, &prompts, 10);
    for mode in [PrefillMode::Batched, PrefillMode::PerToken] {
        let (batched, stats) = m.generate_with(&prompts, 10, mode).unwrap();
        assert_eq!(batched, solo, "{mode:?}: batchmates leaked into a row");
        assert_eq!(stats.prompt_lens, vec![8, 3, 5]);
        assert_eq!(stats.prompt_len, 8);
    }
}

#[test]
fn ragged_batch_is_independent_of_batchmates_packed() {
    let (_, p) = nano_model(12);
    for bits in [2u32, 3] {
        let m = ServeModel::packed_rtn(&p, bits).unwrap();
        let prompts = vec![vec![9i32, 8, 7, 6, 5, 4, 3], vec![42i32, 100]];
        let solo = solo_rows(&m, &prompts, 8);
        let (batched, _) = m.generate(&prompts, 8).unwrap();
        assert_eq!(batched, solo, "W{bits}: batchmates leaked into a row");
    }
}

#[test]
fn batched_prefill_matches_per_token_packed() {
    // W4 exercises the packed forward across both multi-row (batched
    // prefill) and single-slab (decode) shapes; the two prefill
    // strategies must agree exactly.
    let (_, p) = nano_model(13);
    let m = ServeModel::packed_rtn(&p, 4).unwrap();
    let prompts = vec![vec![5i32, 6, 7, 8, 9, 10], vec![99i32, 1, 2], vec![64i32; 4]];
    let (ob, _) = m.generate_with(&prompts, 6, PrefillMode::Batched).unwrap();
    let (ot, _) = m.generate_with(&prompts, 6, PrefillMode::PerToken).unwrap();
    assert_eq!(ob, ot);
}

#[test]
fn decode_stats_report_prefill_and_per_row_lengths() {
    let (cfg, p) = nano_model(14);
    let m = ServeModel::dense(&p);
    let prompts = vec![vec![1i32, 2, 3, 4], vec![5i32, 6]];
    let (outs, stats) = m.generate(&prompts, 5).unwrap();
    assert_eq!(stats.batch, 2);
    assert_eq!(stats.new_tokens, 5);
    assert_eq!(stats.prompt_lens, vec![4, 2]);
    assert_eq!(stats.prompt_len, 4);
    assert!(stats.prefill_s > 0.0, "prefill time not recorded");
    assert!(stats.decode_s > 0.0, "decode time not recorded");
    assert!(stats.tokens_per_s > 0.0);
    assert!(stats.prefill_tokens_per_s > 0.0);
    assert!(stats.weight_bytes > 0);
    for o in &outs {
        assert_eq!(o.len(), 5);
        assert!(o.iter().all(|&t| t >= 0 && (t as usize) < cfg.vocab_size));
    }
}

#[test]
fn gateway_eviction_preserves_survivors() {
    // evicting ANY subset of rows mid-decode (deadline eviction forced
    // by a synthetic slow step) leaves every surviving request
    // bit-identical to its solo run — the serving-gateway extension of
    // the ragged-batch independence contract
    use std::rc::Rc;
    use tesseraq::robust::FaultPlan;
    use tesseraq::serve::{Gateway, GatewayConfig, Request, RequestOutcome};

    let (cfg, p) = nano_model(16);
    let m = ServeModel::dense(&p);
    tesseraq::util::proptest(6, 0xE71C7, |rng| {
        let n = 2 + rng.below(4);
        let max_batch = 1 + rng.below(3);
        let mut prompts: Vec<Vec<i32>> = Vec::new();
        let mut victims: Vec<usize> = Vec::new();
        for i in 0..n {
            let len = 1 + rng.below(6);
            prompts.push((0..len).map(|_| rng.below(cfg.vocab_size) as i32).collect());
            if rng.below(2) == 1 {
                victims.push(i);
            }
        }
        let new = 1 + rng.below(4);
        let gcfg = GatewayConfig {
            queue_depth: 16,
            max_batch,
            kv_slot_budget: 512,
            ..Default::default()
        };
        // decode step 1 "takes" 10^7 ms: every deadlined request (victim)
        // is evicted mid-batch or expires in queue; the rest are untouched
        let plan = Rc::new(FaultPlan::parse("slow@1.10000000").unwrap());
        let mut gw = Gateway::new(&m, gcfg).with_faults(plan);
        let ids: Vec<u64> = prompts
            .iter()
            .enumerate()
            .map(|(i, pr)| {
                let mut req = Request::new(pr.clone(), new);
                if victims.contains(&i) {
                    req = req.with_deadline(5_000);
                }
                gw.submit(req).unwrap()
            })
            .collect();
        gw.drain();
        assert_eq!(gw.kv_in_use(), 0, "leaked KV accounting");
        let c = gw.counters();
        assert_eq!(c.admitted, c.completed + c.deadline_missed + c.failed);
        for (i, id) in ids.iter().enumerate() {
            let out = &gw.outcomes()[id];
            if victims.contains(&i) {
                assert!(
                    matches!(out, RequestOutcome::DeadlineMissed { .. }),
                    "victim {i}: expected deadline miss, got {out:?}"
                );
            } else {
                match out {
                    RequestOutcome::Completed { tokens, .. } => {
                        let (solo, _) =
                            m.generate(std::slice::from_ref(&prompts[i]), new).unwrap();
                        assert_eq!(
                            tokens, &solo[0],
                            "survivor {i} perturbed by eviction of {victims:?}"
                        );
                    }
                    other => panic!("survivor {i}: expected completion, got {other:?}"),
                }
            }
        }
    });
}

#[test]
fn ragged_equivalence_proptest() {
    // random ragged batches: every row must equal its solo run exactly
    let (cfg, p) = nano_model(15);
    let m = ServeModel::dense(&p);
    tesseraq::util::proptest(6, 0x5EED5, |rng| {
        let b = 1 + rng.below(3);
        let prompts: Vec<Vec<i32>> = (0..b)
            .map(|_| {
                let len = 1 + rng.below(9);
                (0..len).map(|_| rng.below(cfg.vocab_size) as i32).collect()
            })
            .collect();
        let new = 1 + rng.below(5);
        let (batched, _) = m.generate(&prompts, new).unwrap();
        for (r, prompt) in prompts.iter().enumerate() {
            let (solo, _) = m.generate(std::slice::from_ref(prompt), new).unwrap();
            assert_eq!(batched[r], solo[0], "row {r} of {prompts:?} (new={new})");
        }
    });
}
