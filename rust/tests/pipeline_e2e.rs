//! Full-pipeline E2E on nano: pretrain -> quantize (several methods) ->
//! evaluate -> pack -> serve. Verifies that the paper-shaped orderings
//! hold end to end and that the packed serving path agrees with the
//! fake-quantized evaluation path.

use tesseraq::data::{Corpus, CorpusKind, Task, TaskKind};
use tesseraq::eval::Evaluator;
use tesseraq::experiments::methods::{quantize, Method, MethodOpts};
use tesseraq::experiments::Ctx;
use tesseraq::quant::{GroupScheme, QuantConfig};
use tesseraq::serve::ServeModel;

fn ctx() -> Option<Ctx> {
    let dir = tesseraq::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return None;
    }
    Some(Ctx::new(true).expect("ctx"))
}

#[test]
fn e2e_methods_ordering_nano() {
    let Some(ctx) = ctx() else { return };
    let size = "nano";
    let base = ctx.base_model(size, CorpusKind::WikiLike).expect("base");
    let corpus = Corpus::new(CorpusKind::WikiLike, base.cfg.vocab_size);
    let ev = Evaluator::new(&ctx.eng, size).expect("eval");
    let qcfg = QuantConfig::weight_only(2, GroupScheme::Group(32));
    let opts = MethodOpts::new(qcfg, 16, true);

    let mut ppl = std::collections::BTreeMap::new();
    ppl.insert(
        "fp",
        ev.perplexity(&base, None, 65535.0, &corpus, 16, 1).unwrap(),
    );
    for (key, m) in [("rtn", Method::Rtn), ("awq", Method::Awq), ("tq", Method::TesseraQ)] {
        let q = quantize(&ctx.eng, &base, m, &qcfg, &corpus, &opts).expect(key);
        ppl.insert(
            key,
            ev.perplexity(&q.params, q.head_t.as_ref(), qcfg.qmax_act(), &corpus, 16, 1)
                .unwrap(),
        );
    }
    eprintln!("e2e ppl: {ppl:?}");
    // paper shape: FP <= TesseraQ < RTN; AWQ between
    assert!(ppl["fp"] <= ppl["tq"] + 1e-9);
    assert!(ppl["tq"] < ppl["rtn"], "TesseraQ must beat RTN");
    assert!(ppl["awq"] <= ppl["rtn"] * 1.05, "AWQ should not be worse than RTN");
}

#[test]
fn e2e_packed_serving_matches_fakequant_eval() {
    let Some(ctx) = ctx() else { return };
    let size = "nano";
    let base = ctx.base_model(size, CorpusKind::WikiLike).expect("base");
    let corpus = Corpus::new(CorpusKind::WikiLike, base.cfg.vocab_size);
    let qcfg = QuantConfig::weight_only(4, GroupScheme::Group(32));
    let opts = MethodOpts::new(qcfg, 16, true);
    let q = quantize(&ctx.eng, &base, Method::TesseraQ, &qcfg, &corpus, &opts).unwrap();

    // packed weights must dequantize exactly to the merged fake-quant
    // weights the evaluator saw
    let report = q.report.as_ref().unwrap();
    let packed = ServeModel::packed(&q.params, report, qcfg.w_bits).unwrap();
    let dense = ServeModel::dense(&q.params);
    let prompts = vec![corpus.sample(12, 0), corpus.sample(12, 1)];
    let (out_p, stats_p) = packed.generate(&prompts, 16).unwrap();
    let (out_d, stats_d) = dense.generate(&prompts, 16).unwrap();
    assert_eq!(out_p, out_d, "packed and dense decode diverged");
    assert!(
        stats_p.weight_bytes < stats_d.weight_bytes / 2,
        "packed model not smaller: {} vs {}",
        stats_p.weight_bytes,
        stats_d.weight_bytes
    );
}

#[test]
fn e2e_zeroshot_ranking_runs_on_quantized_model() {
    let Some(ctx) = ctx() else { return };
    let size = "nano";
    let base = ctx.base_model(size, CorpusKind::WikiLike).expect("base");
    let corpus = Corpus::new(CorpusKind::WikiLike, base.cfg.vocab_size);
    let ev = Evaluator::new(&ctx.eng, size).expect("eval");
    let task = Task::generate(TaskKind::PiqaS, &corpus, 40, 12);
    let acc_fp = ev.zeroshot(&base, None, 65535.0, &task).unwrap();
    // trained model must beat coin flip on the easiest task
    assert!(acc_fp > 0.55, "FP accuracy only {acc_fp}");
    let qcfg = QuantConfig::weight_only(3, GroupScheme::Group(32));
    let opts = MethodOpts::new(qcfg, 16, true);
    let q = quantize(&ctx.eng, &base, Method::TesseraQ, &qcfg, &corpus, &opts).unwrap();
    let acc_q = ev
        .zeroshot(&q.params, q.head_t.as_ref(), qcfg.qmax_act(), &task)
        .unwrap();
    eprintln!("piqa-s: fp {acc_fp:.3} w3 {acc_q:.3}");
    assert!(acc_q > 0.5, "3-bit model collapsed to chance");
}

#[test]
fn e2e_zeroshot_handles_empty_prefix() {
    let Some(ctx) = ctx() else { return };
    let size = "nano";
    let base = ctx.base_model(size, CorpusKind::WikiLike).expect("base");
    let ev = Evaluator::new(&ctx.eng, size).expect("eval");
    // zero-length task prefixes used to underflow `start - 1` when
    // scoring candidates and panic the whole suite
    let items = (0..4i32)
        .map(|i| tesseraq::data::TaskItem {
            prefix: vec![],
            cand: [vec![1 + i, 2, 3], vec![4, 5 + i, 6]],
            label: (i % 2) as usize,
        })
        .collect();
    let task = Task { kind: TaskKind::PiqaS, items };
    let acc = ev.zeroshot(&base, None, 65535.0, &task).unwrap();
    assert!((0.0..=1.0).contains(&acc), "accuracy out of range: {acc}");
}

#[test]
fn e2e_rotation_path_evaluates() {
    let Some(ctx) = ctx() else { return };
    let size = "nano";
    let base = ctx.base_model(size, CorpusKind::WikiLike).expect("base");
    let corpus = Corpus::new(CorpusKind::WikiLike, base.cfg.vocab_size);
    let ev = Evaluator::new(&ctx.eng, size).expect("eval");
    // rotation without quantization must preserve PPL exactly-ish
    let mut rotated = base.clone();
    let head_t = tesseraq::quant::rotate::rotate_model(&mut rotated, 0x1207);
    let ppl_base = ev.perplexity(&base, None, 65535.0, &corpus, 16, 5).unwrap();
    let ppl_rot = ev
        .perplexity(&rotated, Some(&head_t), 65535.0, &corpus, 16, 5)
        .unwrap();
    assert!(
        (ppl_base - ppl_rot).abs() / ppl_base < 1e-3,
        "rotation broke equivalence: {ppl_base} vs {ppl_rot}"
    );
    // and under W4A4 the rotated model should not be (much) worse
    let qcfg = QuantConfig::new(4, GroupScheme::PerChannel, Some(4));
    let opts = MethodOpts::new(qcfg, 16, true);
    let q_rot = quantize(&ctx.eng, &base, Method::QuaRotGptq, &qcfg, &corpus, &opts).unwrap();
    let ppl_q = ev
        .perplexity(&q_rot.params, q_rot.head_t.as_ref(), qcfg.qmax_act(), &corpus, 16, 5)
        .unwrap();
    eprintln!("rot: fp {ppl_base:.3} rot {ppl_rot:.3} w4a4+rot+gptq {ppl_q:.3}");
    assert!(ppl_q.is_finite());
}
