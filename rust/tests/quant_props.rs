//! Property-based tests over quantizer/coordinator invariants (seeded
//! random-case driver from util::proptest — offline env has no proptest
//! crate; failing seeds are reported for replay).

use tesseraq::quant::{
    self, dequant_codes, dst_effective_scale, hard_codes, minmax_scale, nu_init,
    rtn_codes, rtn_qdq, w_floor, ClipFactors,
};
use tesseraq::quant::pack::{pack_codes, unpack_codes, PackedLinear};
use tesseraq::tensor::{linalg, Pcg32, Tensor};
use tesseraq::util::proptest;

fn rand_weight(rng: &mut Pcg32) -> (Tensor, usize) {
    let o = 1 + rng.below(24);
    let groups = 1 + rng.below(4);
    let g = [4, 8, 16, 32][rng.below(4)];
    let i = groups * g;
    let scale = 0.1 + rng.uniform() as f32 * 3.0;
    (Tensor::randn(&[o, i], scale, rng), g)
}

#[test]
fn prop_rtn_codes_in_range_and_error_bounded() {
    proptest(40, 100, |rng| {
        let (w, g) = rand_weight(rng);
        let bits = [2u32, 3, 4, 8][rng.below(4)];
        let qmax = (2u32.pow(bits) - 1) as f32;
        let qp = minmax_scale(&w, g, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), qmax);
        let codes = rtn_codes(&w, &qp, qmax);
        assert!(codes.iter().all(|&c| (c as f32) <= qmax));
        let what = rtn_qdq(&w, &qp, qmax);
        let (o, i) = w.dims2();
        let ng = qp.n_groups();
        for r in 0..o {
            for c in 0..i {
                let s = qp.s.data[r * ng + c / g];
                let err = (w.data[r * i + c] - what.data[r * i + c]).abs();
                // |err| <= s (0.5 rounding + 0.5 zero-point rounding slack)
                assert!(err <= s + 1e-5, "err {err} > step {s}");
            }
        }
    });
}

#[test]
fn prop_dequant_of_codes_matches_rtn_qdq() {
    proptest(30, 200, |rng| {
        let (w, g) = rand_weight(rng);
        let qmax = 15.0;
        let qp = minmax_scale(&w, g, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), qmax);
        let codes = rtn_codes(&w, &qp, qmax);
        let (o, i) = w.dims2();
        let via_codes = dequant_codes(&codes, o, i, &qp);
        let direct = rtn_qdq(&w, &qp, qmax);
        assert!(via_codes.mse(&direct) < 1e-12);
    });
}

#[test]
fn prop_pack_roundtrip_arbitrary_shapes() {
    proptest(60, 300, |rng| {
        let bits = [2u32, 3, 4][rng.below(3)];
        let o = 1 + rng.below(20);
        let i = 1 + rng.below(90);
        let codes: Vec<u16> = (0..o * i).map(|_| rng.below(1 << bits) as u16).collect();
        let (words, _) = pack_codes(&codes, o, i, bits);
        assert_eq!(unpack_codes(&words, o, i, bits), codes);
    });
}

#[test]
fn prop_packed_forward_equals_dense_dequant() {
    proptest(20, 400, |rng| {
        let bits = [2u32, 3, 4][rng.below(3)];
        let g = [8usize, 16][rng.below(2)];
        let ng = 1 + rng.below(3);
        let i = g * ng;
        let o = 1 + rng.below(30);
        let m = 1 + rng.below(10);
        let qmax = (2u32.pow(bits) - 1) as f32;
        let w = Tensor::randn(&[o, i], 1.0, rng);
        let qp = minmax_scale(&w, g, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), qmax);
        let codes = rtn_codes(&w, &qp, qmax);
        let pl = PackedLinear::from_codes(&codes, o, i, bits, qp).unwrap();
        let x = Tensor::randn(&[m, i], 1.0, rng);
        use tesseraq::model::hostfwd::LinearOp;
        let got = pl.forward(&x);
        let want = linalg::matmul_bt(&x, &pl.dequant_dense());
        assert!(got.mse(&want).sqrt() < 1e-4);
    });
}

#[test]
fn prop_hard_codes_equal_rtn_when_nu_from_init() {
    // alpha = 1[nu_init > 0] == RTN rounding, for any weights/clips
    proptest(40, 500, |rng| {
        let (w, g) = rand_weight(rng);
        let bits = [2u32, 4][rng.below(2)];
        let qmax = (2u32.pow(bits) - 1) as f32;
        let clip = 0.6 + rng.uniform() as f32 * 0.4;
        let qp = minmax_scale(&w, g, &ClipFactors::Uniform(clip),
                              &ClipFactors::Uniform(clip), qmax);
        let wf = w_floor(&w, &qp);
        let nu = nu_init(&w, &qp);
        let hard = hard_codes(&wf, &nu, &qp, qmax);
        let rtn = rtn_codes(&w, &qp, qmax);
        // identical except at exact .5 ties (rounding direction differs):
        // allow a small fraction of off-by-one disagreements
        let diff = hard.iter().zip(&rtn).filter(|(a, b)| a != b).count();
        assert!(
            diff * 100 <= hard.len().max(100),
            "{diff}/{} hard-vs-rtn mismatches",
            hard.len()
        );
    });
}

#[test]
fn prop_dst_scale_monotone_in_v() {
    proptest(30, 600, |rng| {
        let (w, g) = rand_weight(rng);
        let qp = minmax_scale(&w, g, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), 15.0);
        let v1 = Tensor::randn(&qp.s.shape, 1.0, rng);
        let v2 = v1.map(|x| x + 0.5);
        let s1 = dst_effective_scale(&qp, &v1);
        let s2 = dst_effective_scale(&qp, &v2);
        for ((a, b), base) in s1.s.data.iter().zip(&s2.s.data).zip(&qp.s.data) {
            assert!(b > a, "2sigmoid(v)s must be increasing in v");
            assert!(*a > 0.0 && *b < 2.0 * base + 1e-6);
        }
    });
}

#[test]
fn prop_act_fakequant_idempotent() {
    // fake-quantizing an already fake-quantized row is (nearly) a no-op
    proptest(30, 700, |rng| {
        let width = [8usize, 16, 32][rng.below(3)];
        let rows = 1 + rng.below(6);
        let qmax = [7.0f32, 15.0, 255.0][rng.below(3)];
        let mut x: Vec<f32> = (0..rows * width).map(|_| rng.normal() as f32).collect();
        quant::act_fakequant_rows(&mut x, width, qmax);
        let once = x.clone();
        quant::act_fakequant_rows(&mut x, width, qmax);
        for (a, b) in x.iter().zip(&once) {
            assert!((a - b).abs() < 2e-2, "far from idempotent: {a} vs {b}");
        }
    });
}

#[test]
fn prop_hadamard_involution_random_dims() {
    proptest(20, 800, |rng| {
        let n = [8usize, 16, 32, 64, 128][rng.below(5)];
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut y = x.clone();
        linalg::hadamard_inplace(&mut y, n);
        linalg::hadamard_inplace(&mut y, n);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-4);
        }
    });
}

#[test]
fn prop_count_flips_never_exceeds_total() {
    proptest(20, 900, |rng| {
        let (w, g) = rand_weight(rng);
        let qp = minmax_scale(&w, g, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), 3.0);
        let mut nu = nu_init(&w, &qp);
        for v in nu.data.iter_mut() {
            if rng.uniform() < 0.2 {
                *v = -*v - 0.05;
            }
        }
        let flips = quant::count_flips(&w, &nu, &qp);
        assert!(flips <= nu.data.len());
    });
}
