//! Integration tests for the unified block-reconstruction driver
//! (ISSUE acceptance criteria): every method that runs through
//! `ReconstructionDriver` — not just TesseraQ — must survive a mid-run
//! kill and resume bit-identically, and the sentinel rollback must keep
//! a poisoned step from leaking into the final clips.
//!
//! Everything runs on the host path (`eng = None`) so the tests are
//! device-independent; `chaos_drill_env_faults_never_poison` additionally
//! honours `TESSERAQ_FAULTS`, which is what the CI fault matrix drives.

use std::path::PathBuf;
use std::rc::Rc;

use tesseraq::coordinator::lwc::{calibrate_lwc_with, LwcConfig, LwcOptimizer};
use tesseraq::data::{Corpus, CorpusKind};
use tesseraq::experiments::methods::gptq_model;
use tesseraq::model::{ModelConfig, Params, PARAM_NAMES};
use tesseraq::quant::{GroupScheme, QuantConfig};
use tesseraq::robust::{FaultPlan, RobustConfig, SentinelConfig, KILL_MARKER};
use tesseraq::tensor::Pcg32;

const N_SEQ: usize = 2;

fn setup() -> (Params, Vec<i32>, QuantConfig) {
    let cfg = ModelConfig::preset("nano").expect("nano preset");
    let mut rng = Pcg32::seeded(0xB0B);
    let params = Params::init(&cfg, &mut rng);
    let corpus = Corpus::new(CorpusKind::WikiLike, cfg.vocab_size);
    let tokens = corpus.sequences(N_SEQ, cfg.max_seq, 0xCA11B);
    let qcfg = QuantConfig::weight_only(2, GroupScheme::Group(32));
    (params, tokens, qcfg)
}

fn test_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("tesseraq_driver_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn assert_params_eq(a: &Params, b: &Params, what: &str) {
    for name in PARAM_NAMES {
        assert_eq!(a.get(name).data, b.get(name).data, "param {name} diverged ({what})");
    }
}

#[test]
fn gptq_kill_resume_bit_identical() {
    let (base, tokens, qcfg) = setup();
    let dir = test_dir("gptq_resume");

    // uninterrupted reference run
    let mut p_ref = base.clone();
    let report_ref =
        gptq_model(None, &mut p_ref, &tokens, N_SEQ, &qcfg, &RobustConfig::default())
            .expect("reference run");
    assert_eq!(report_ref.per_block.len(), base.cfg.n_layers);

    // same run, killed right after block 0's checkpoint is persisted
    let mut robust = RobustConfig::with_checkpoints(&dir, false);
    robust.faults = Some(Rc::new(FaultPlan::parse("kill@0").unwrap()));
    let mut p_killed = base.clone();
    let err = gptq_model(None, &mut p_killed, &tokens, N_SEQ, &qcfg, &robust)
        .expect_err("injected kill must abort the run");
    assert!(format!("{err:#}").contains(KILL_MARKER), "unexpected error: {err:#}");

    // resume from the surviving checkpoints
    let mut p_resumed = base.clone();
    let report_resumed = gptq_model(
        None,
        &mut p_resumed,
        &tokens,
        N_SEQ,
        &qcfg,
        &RobustConfig::with_checkpoints(&dir, true),
    )
    .expect("resumed run");

    assert_eq!(report_resumed.quantized, report_ref.quantized);
    assert_eq!(report_resumed.per_block, report_ref.per_block);
    assert_params_eq(&p_resumed, &p_ref, "GPTQ resume");

    let _ = std::fs::remove_dir_all(&dir);
}

/// A deterministic, lr-independent scripted step: decay the clip logits a
/// little each call and report a decreasing loss. Stateless across blocks
/// (the driver re-inits the block state), so a resumed run replays the
/// exact same trajectory.
fn scripted_step(
) -> Box<dyn FnMut(&mut tesseraq::coordinator::lwc::LwcBlockState, usize, f32) -> anyhow::Result<f32>>
{
    Box::new(|state, t, _lr| {
        for g in state.gam.values_mut() {
            for v in &mut g.data {
                *v *= 0.98;
            }
        }
        for b in state.bet.values_mut() {
            for v in &mut b.data {
                *v *= 0.97;
            }
        }
        Ok(1.0 / t as f32)
    })
}

#[test]
fn lwc_kill_resume_bit_identical() {
    let (base, tokens, qcfg) = setup();
    let dir = test_dir("lwc_resume");
    let lcfg = LwcConfig::fast(qcfg);
    let size = base.cfg.name.clone();

    // uninterrupted reference run with the scripted step
    let defaults = RobustConfig::default();
    let mut opt_ref = LwcOptimizer::new(None, &size, &lcfg, N_SEQ, &defaults).unwrap();
    opt_ref.step_override = Some(scripted_step());
    let mut p_ref = base.clone();
    let report_ref =
        calibrate_lwc_with(None, &mut p_ref, &mut opt_ref, &tokens, N_SEQ, &defaults)
            .expect("reference run");
    assert_eq!(report_ref.per_block.len(), base.cfg.n_layers);
    assert!(report_ref.fallback_blocks().is_empty(), "scripted step must not degrade");

    // killed after block 0
    let mut robust = RobustConfig::with_checkpoints(&dir, false);
    robust.faults = Some(Rc::new(FaultPlan::parse("kill@0").unwrap()));
    let mut opt_killed = LwcOptimizer::new(None, &size, &lcfg, N_SEQ, &robust).unwrap();
    opt_killed.step_override = Some(scripted_step());
    let mut p_killed = base.clone();
    let err =
        calibrate_lwc_with(None, &mut p_killed, &mut opt_killed, &tokens, N_SEQ, &robust)
            .expect_err("injected kill must abort the run");
    assert!(format!("{err:#}").contains(KILL_MARKER), "unexpected error: {err:#}");

    // resumed: restored blocks rebuild their clips from checkpoint extras
    let resume = RobustConfig::with_checkpoints(&dir, true);
    let mut opt_resumed = LwcOptimizer::new(None, &size, &lcfg, N_SEQ, &resume).unwrap();
    opt_resumed.step_override = Some(scripted_step());
    let mut p_resumed = base.clone();
    let report_resumed =
        calibrate_lwc_with(None, &mut p_resumed, &mut opt_resumed, &tokens, N_SEQ, &resume)
            .expect("resumed run");

    assert_eq!(report_resumed.quantized, report_ref.quantized);
    assert_eq!(report_resumed.per_block, report_ref.per_block);
    assert_eq!(opt_resumed.clips, opt_ref.clips, "learned clips diverged after resume");
    assert_params_eq(&p_resumed, &p_ref, "LWC resume");

    let _ = std::fs::remove_dir_all(&dir);
}

/// A scripted step that additionally corrupts the clip logits on its first
/// call at step `t == 2` — paired with a `nan@0.2` fault, the sentinel
/// must roll that iteration back so the corruption never reaches the
/// final clips.
fn corrupting_step(
) -> Box<dyn FnMut(&mut tesseraq::coordinator::lwc::LwcBlockState, usize, f32) -> anyhow::Result<f32>>
{
    let mut corrupted = false;
    let mut clean = scripted_step();
    Box::new(move |state, t, lr| {
        if t == 2 && !corrupted {
            corrupted = true;
            for g in state.gam.values_mut() {
                for v in &mut g.data {
                    *v += 1000.0;
                }
            }
            // the paired NaN fault flags this step; report a normal loss
            return Ok(1.0 / t as f32);
        }
        clean(state, t, lr)
    })
}

#[test]
fn lwc_nan_rolls_back_poisoned_step() {
    let (base, tokens, qcfg) = setup();
    let lcfg = LwcConfig::fast(qcfg);
    let size = base.cfg.name.clone();

    // clean reference: scripted step, no faults
    let defaults = RobustConfig::default();
    let mut opt_ref = LwcOptimizer::new(None, &size, &lcfg, N_SEQ, &defaults).unwrap();
    opt_ref.step_override = Some(scripted_step());
    let mut p_ref = base.clone();
    let report_ref =
        calibrate_lwc_with(None, &mut p_ref, &mut opt_ref, &tokens, N_SEQ, &defaults)
            .expect("reference run");

    // faulted run: block 0 step 2 corrupts the logits AND reports NaN loss.
    // The sentinel rolls back to the iteration-start snapshot and retries;
    // the scripted step is lr-independent, so the retry reproduces the
    // clean trajectory exactly.
    let mut robust = RobustConfig::default();
    robust.faults = Some(Rc::new(FaultPlan::parse("nan@0.2").unwrap()));
    let mut opt_nan = LwcOptimizer::new(None, &size, &lcfg, N_SEQ, &robust).unwrap();
    opt_nan.step_override = Some(corrupting_step());
    let mut p_nan = base.clone();
    let report_nan =
        calibrate_lwc_with(None, &mut p_nan, &mut opt_nan, &tokens, N_SEQ, &robust)
            .expect("faulted run must complete");

    assert_eq!(report_nan.per_block, report_ref.per_block);
    assert_eq!(report_nan.quantized, report_ref.quantized);
    assert_eq!(opt_nan.clips, opt_ref.clips, "rollback must discard the corruption");
    assert_params_eq(&p_nan, &p_ref, "sentinel rollback");

    // contrast: with the sentinel disabled the NaN sails through, nothing
    // rolls back, and the corrupted logits poison block 0's clips
    let mut unguarded = RobustConfig::default();
    unguarded.sentinel = SentinelConfig::disabled();
    unguarded.faults = Some(Rc::new(FaultPlan::parse("nan@0.2").unwrap()));
    let mut opt_raw = LwcOptimizer::new(None, &size, &lcfg, N_SEQ, &unguarded).unwrap();
    opt_raw.step_override = Some(corrupting_step());
    let mut p_raw = base.clone();
    calibrate_lwc_with(None, &mut p_raw, &mut opt_raw, &tokens, N_SEQ, &unguarded)
        .expect("unguarded run still completes");
    assert_ne!(
        opt_raw.clips.get(&0),
        opt_ref.clips.get(&0),
        "without the sentinel the corruption must be visible (test is vacuous otherwise)"
    );
}

/// CI chaos drill: whatever `TESSERAQ_FAULTS` injects, a driver run either
/// completes cleanly or dies with the kill marker — and resuming past the
/// kills converges to the exact fault-free result. With the env var unset
/// this degenerates to a plain run (still a useful smoke test).
#[test]
fn chaos_drill_env_faults_never_poison() {
    let (base, tokens, qcfg) = setup();
    let dir = test_dir("chaos");

    let mut p_ref = base.clone();
    let report_ref =
        gptq_model(None, &mut p_ref, &tokens, N_SEQ, &qcfg, &RobustConfig::default())
            .expect("reference run");

    let mut robust = RobustConfig::with_checkpoints(&dir, false);
    robust.faults = FaultPlan::from_env();
    let mut report = None;
    // one fresh attempt + at most one resume per block's kill site
    for attempt in 0..=base.cfg.n_layers + 1 {
        let mut p = base.clone();
        match gptq_model(None, &mut p, &tokens, N_SEQ, &qcfg, &robust) {
            Ok(rep) => {
                assert!(
                    PARAM_NAMES
                        .iter()
                        .all(|n| p.get(n).data.iter().all(|v| v.is_finite())),
                    "non-finite weights after chaos run"
                );
                assert_params_eq(&p, &p_ref, "chaos drill");
                report = Some(rep);
                break;
            }
            Err(e) => {
                assert!(
                    format!("{e:#}").contains(KILL_MARKER),
                    "attempt {attempt}: only injected kills may abort, got: {e:#}"
                );
                robust.resume = true;
            }
        }
    }
    let report = report.expect("run never completed within the resume budget");
    assert_eq!(report.quantized, report_ref.quantized);
    assert_eq!(report.per_block, report_ref.per_block);

    let _ = std::fs::remove_dir_all(&dir);
}
