//! Structured telemetry: spans, metrics, and a JSONL event sink.
//!
//! Zero-dependency (hand-rolled, like the rest of the offline vendor
//! set) and zero-cost when disabled: every public entry point is gated
//! on one relaxed atomic load, so an untraced calibration pays a single
//! branch per call site. Enabled via `--trace-out DIR` / `TESSERAQ_TRACE`:
//!
//! * [`sink`] — the JSONL event sink. One event per line appended (never
//!   clobbered — a resumed run extends the interrupted run's trace) to
//!   `<dir>/trace.jsonl`, plus a `manifest.json` tying every run to its
//!   checkpoint config fingerprint.
//! * [`span`] — hierarchical RAII spans (`span!("block", idx)`) recording
//!   wall time, self time (wall minus child spans), and parent/child
//!   structure.
//! * [`metrics`] — a global registry of counters, gauges, and histograms
//!   with fixed log2 buckets; flushed as `metric` events.
//! * [`summary`] — `repro trace-summary <run>`: renders a per-phase
//!   self-time profile and a per-block loss table from a trace file.
//!
//! Event kinds emitted across the codebase: `telemetry_init`, `run_start`,
//! `run_end`, `span_open`, `span_close`, `block_done`, `par_iter`,
//! `lwc_iter`, `rollback`, `retry`, `retry_recovered`, `fallback`,
//! `degraded`, `resume`, `resume_stop`, `checkpoint_write`,
//! `checkpoint_load`, `fault_injected`, `fault_spec_invalid`,
//! `serve_request`, `bench`, `metric`, `warn`, and from the serving
//! gateway: `gateway_admit`, `gateway_shed`, `gateway_complete`,
//! `gateway_deadline_miss`, `gateway_degrade`, `gateway_session_abort`,
//! `gateway_request_failed` (histograms `gateway.queue_depth`,
//! `gateway.time_in_queue_ms`, `gateway.request_latency_ms`,
//! `gateway.decode_step_us`).

pub mod metrics;
pub mod sink;
pub mod span;
pub mod summary;

pub use metrics::{counter_add, flush_metrics, gauge_set, hist_record, Histogram};
pub use sink::{enabled, event, init, init_from_env, run_start, shutdown, trace_dir, warn, Val};
pub use span::{enter, SpanGuard};

/// RAII span macro: `span!("phase")` or `span!("block", idx)` (the second
/// argument becomes the span's `detail` via `Display`). Bind the result —
/// `let _sp = span!(...)` — so the guard lives to the end of the scope.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::obs::enter($name, None)
    };
    ($name:expr, $detail:expr) => {
        $crate::obs::enter($name, Some(format!("{}", $detail)))
    };
}
