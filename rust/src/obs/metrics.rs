//! Metrics registry: counters, gauges, and log2-bucket histograms.
//!
//! Global, mutex-guarded (the engine runs scoped worker threads), and
//! inert when the sink is disabled — each free function early-returns on
//! one relaxed atomic load. [`flush_metrics`] serializes every metric as
//! one `metric` event; the driver flushes at the end of each run and the
//! sink flushes again on shutdown.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::obs::sink::{enabled, event};

/// Log2 bucket count: bucket 0 catches v < 1 (and non-finite values),
/// bucket i >= 1 covers [2^(i-1), 2^i), the last bucket is open-ended.
/// 2^38 ns ≈ 4.6 min — comfortably above any single measurement here.
pub const N_BUCKETS: usize = 40;

/// Fixed log-scale histogram. Bucket boundaries are exact powers of two
/// computed from the f64 exponent bits, so values like 2.0 land in the
/// [2, 4) bucket without float-log rounding surprises.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub count: u64,
    pub sum: f64,
    pub buckets: [u64; N_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { count: 0, sum: 0.0, buckets: [0; N_BUCKETS] }
    }
}

impl Histogram {
    /// Bucket index for `v`: 0 for v < 1 (or NaN), else exponent + 1
    /// clamped to the last bucket.
    pub fn bucket_index(v: f64) -> usize {
        if v.is_nan() || v < 1.0 {
            return 0;
        }
        let e = ((v.to_bits() >> 52) & 0x7ff) as isize - 1023;
        ((e + 1).max(1) as usize).min(N_BUCKETS - 1)
    }

    /// Upper bound of bucket `i` (inclusive lower bound is
    /// `bucket_bound(i - 1)`); bucket 0's bound is 1.
    pub fn bucket_bound(i: usize) -> f64 {
        (2.0f64).powi(i as i32)
    }

    pub fn record(&mut self, v: f64) {
        self.count += 1;
        if v.is_finite() {
            self.sum += v;
        }
        self.buckets[Self::bucket_index(v)] += 1;
    }

    /// Quantile estimate: the upper bound of the bucket holding the q-th
    /// sample. Coarse (factor-of-two) but monotone and allocation-free.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return Self::bucket_bound(i);
            }
        }
        Self::bucket_bound(N_BUCKETS - 1)
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

#[derive(Default)]
struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Histogram>,
}

static REGISTRY: Mutex<Option<Registry>> = Mutex::new(None);

fn with_registry(f: impl FnOnce(&mut Registry)) {
    let mut g = REGISTRY.lock().unwrap_or_else(|p| p.into_inner());
    f(g.get_or_insert_with(Registry::default));
}

/// Add to a monotonic counter. No-op when the sink is disabled.
pub fn counter_add(name: &str, delta: u64) {
    if !enabled() {
        return;
    }
    with_registry(|r| *r.counters.entry(name.to_string()).or_insert(0) += delta);
}

/// Set a gauge to its latest value. No-op when the sink is disabled.
pub fn gauge_set(name: &str, v: f64) {
    if !enabled() {
        return;
    }
    with_registry(|r| {
        r.gauges.insert(name.to_string(), v);
    });
}

/// Record one histogram sample. No-op when the sink is disabled.
pub fn hist_record(name: &str, v: f64) {
    if !enabled() {
        return;
    }
    with_registry(|r| r.hists.entry(name.to_string()).or_default().record(v));
}

/// Emit every metric as a `metric` event and reset the registry (each
/// flush covers the interval since the previous one).
pub fn flush_metrics() {
    if !enabled() {
        return;
    }
    let taken = {
        let mut g = REGISTRY.lock().unwrap_or_else(|p| p.into_inner());
        g.take()
    };
    let Some(r) = taken else { return };
    for (name, v) in &r.counters {
        event(
            "metric",
            &[("metric", name.as_str().into()), ("type", "counter".into()), ("value", (*v).into())],
        );
    }
    for (name, v) in &r.gauges {
        event(
            "metric",
            &[("metric", name.as_str().into()), ("type", "gauge".into()), ("value", (*v).into())],
        );
    }
    for (name, h) in &r.hists {
        // compact non-empty-bucket dump: "i:count" pairs
        let mut buckets = String::new();
        for (i, &b) in h.buckets.iter().enumerate() {
            if b > 0 {
                if !buckets.is_empty() {
                    buckets.push(' ');
                }
                buckets.push_str(&format!("{i}:{b}"));
            }
        }
        event(
            "metric",
            &[
                ("metric", name.as_str().into()),
                ("type", "histogram".into()),
                ("count", h.count.into()),
                ("sum", h.sum.into()),
                ("mean", h.mean().into()),
                ("p50", h.quantile(0.5).into()),
                ("p95", h.quantile(0.95).into()),
                ("buckets", buckets.into()),
            ],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_exact_powers_of_two() {
        assert_eq!(Histogram::bucket_index(0.0), 0);
        assert_eq!(Histogram::bucket_index(0.999), 0);
        assert_eq!(Histogram::bucket_index(f64::NAN), 0);
        assert_eq!(Histogram::bucket_index(-5.0), 0);
        assert_eq!(Histogram::bucket_index(1.0), 1);
        assert_eq!(Histogram::bucket_index(1.9999), 1);
        assert_eq!(Histogram::bucket_index(2.0), 2);
        assert_eq!(Histogram::bucket_index(3.9999), 2);
        assert_eq!(Histogram::bucket_index(4.0), 3);
        assert_eq!(Histogram::bucket_index(1024.0), 11);
        assert_eq!(Histogram::bucket_index(1e300), N_BUCKETS - 1);
    }

    #[test]
    fn quantiles_are_monotone_bucket_bounds() {
        let mut h = Histogram::default();
        for v in [1.0, 1.5, 3.0, 3.5, 100.0] {
            h.record(v);
        }
        assert_eq!(h.count, 5);
        assert!((h.sum - 109.0).abs() < 1e-9);
        // p50 falls in the [2,4) bucket -> bound 4
        assert_eq!(h.quantile(0.5), 4.0);
        // p95+ reaches the [64,128) bucket -> bound 128
        assert_eq!(h.quantile(0.99), 128.0);
        assert!(h.quantile(0.5) <= h.quantile(0.95));
    }

    #[test]
    fn disabled_registry_records_nothing() {
        assert!(!enabled());
        counter_add("x", 3);
        hist_record("h", 1.0);
        let g = REGISTRY.lock().unwrap_or_else(|p| p.into_inner());
        assert!(g.is_none());
    }
}
