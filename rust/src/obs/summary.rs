//! `repro trace-summary <run>`: aggregate a JSONL trace into a per-phase
//! self-time profile and a per-block loss table, rendered through
//! [`crate::report::Table`] like every other result in the repo.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::report::Table;
use crate::util::json::Json;

#[derive(Default)]
struct PhaseAgg {
    count: u64,
    wall_ms: f64,
    self_ms: f64,
}

#[derive(Default)]
struct BlockAgg {
    method: String,
    status: String,
    initial_loss: f64,
    final_loss: f64,
    steps: u64,
    wall_ms: f64,
}

/// Resolve a trace path: a directory means `<dir>/trace.jsonl`.
pub fn resolve_trace(path: &Path) -> PathBuf {
    if path.is_dir() {
        path.join("trace.jsonl")
    } else {
        path.to_path_buf()
    }
}

/// Render the summary for a trace file (or the directory holding it).
pub fn render_summary(path: &Path) -> Result<String> {
    let file = resolve_trace(path);
    let text = std::fs::read_to_string(&file)
        .with_context(|| format!("reading trace {}", file.display()))?;

    let mut n_events = 0usize;
    let mut runs: Vec<(String, String)> = Vec::new();
    let mut phases: BTreeMap<String, PhaseAgg> = BTreeMap::new();
    let mut blocks: BTreeMap<u64, BlockAgg> = BTreeMap::new();
    let mut cur_method = String::new();
    let mut kinds: BTreeMap<String, u64> = BTreeMap::new();

    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .with_context(|| format!("{}:{}: malformed event", file.display(), lineno + 1))?;
        n_events += 1;
        let kind = j.get("kind")?.as_str()?.to_string();
        *kinds.entry(kind.clone()).or_insert(0) += 1;
        match kind.as_str() {
            "run_start" => {
                let fp = j.get("fingerprint")?.as_str()?.to_string();
                cur_method = j.get("method")?.as_str()?.to_string();
                runs.push((fp, cur_method.clone()));
            }
            "span_close" => {
                let name = j.get("name")?.as_str()?.to_string();
                let agg = phases.entry(name).or_default();
                agg.count += 1;
                agg.wall_ms += j.get("wall_ms")?.as_f64().unwrap_or(0.0);
                agg.self_ms += j.get("self_ms")?.as_f64().unwrap_or(0.0);
            }
            "block_done" => {
                let layer = j.get("layer")?.as_f64()? as u64;
                let agg = blocks.entry(layer).or_default();
                agg.method = cur_method.clone();
                agg.status =
                    j.opt("status").and_then(|v| v.as_str().ok()).unwrap_or("?").to_string();
                agg.initial_loss =
                    j.opt("initial_loss").and_then(|v| v.as_f64().ok()).unwrap_or(f64::NAN);
                agg.final_loss =
                    j.opt("final_loss").and_then(|v| v.as_f64().ok()).unwrap_or(f64::NAN);
                agg.steps = j.opt("steps").and_then(|v| v.as_f64().ok()).unwrap_or(0.0) as u64;
                agg.wall_ms = j.opt("wall_ms").and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
            }
            _ => {}
        }
    }
    if n_events == 0 {
        bail!("{}: empty trace", file.display());
    }

    let mut out = String::new();
    let _ = writeln!(out, "trace: {} ({} events)", file.display(), n_events);
    for (fp, method) in &runs {
        let _ = writeln!(out, "run: fingerprint={fp} method={method}");
    }
    let _ = writeln!(out);

    // per-phase self-time profile, hottest self-time first
    let mut profile = Table::new(
        "Per-phase self-time profile",
        &["Phase", "Count", "Wall (ms)", "Self (ms)", "Self %"],
    );
    let total_self: f64 = phases.values().map(|a| a.self_ms).sum();
    let mut rows: Vec<(&String, &PhaseAgg)> = phases.iter().collect();
    rows.sort_by(|a, b| b.1.self_ms.total_cmp(&a.1.self_ms));
    for (name, a) in rows {
        profile.row(vec![
            name.clone(),
            a.count.to_string(),
            format!("{:.2}", a.wall_ms),
            format!("{:.2}", a.self_ms),
            format!("{:.1}", 100.0 * a.self_ms / total_self.max(1e-12)),
        ]);
    }
    out.push_str(&profile.to_markdown());

    // per-block loss table (covers both halves of a resumed run)
    if !blocks.is_empty() {
        let mut bt = Table::new(
            "Per-block reconstruction loss",
            &["Block", "Method", "Status", "Initial", "Final", "Steps", "Wall (ms)"],
        );
        for (layer, a) in &blocks {
            bt.row(vec![
                layer.to_string(),
                a.method.clone(),
                a.status.clone(),
                format!("{:.5}", a.initial_loss),
                format!("{:.5}", a.final_loss),
                a.steps.to_string(),
                format!("{:.1}", a.wall_ms),
            ]);
        }
        out.push_str(&bt.to_markdown());
    }

    // event-kind census: quick schema sanity check for drills
    let mut census = Table::new("Event kinds", &["Kind", "Count"]);
    for (k, n) in &kinds {
        census.row(vec![k.clone(), n.to_string()]);
    }
    out.push_str(&census.to_markdown());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summarizes_a_hand_written_trace() {
        let dir = std::env::temp_dir().join(format!("tsq_sum_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let trace = dir.join("trace.jsonl");
        std::fs::write(
            &trace,
            concat!(
                "{\"seq\":0,\"ts_ms\":1,\"kind\":\"run_start\",\"fingerprint\":\"00ab\",\"method\":\"gptq\"}\n",
                "{\"seq\":1,\"ts_ms\":2,\"kind\":\"span_close\",\"id\":1,\"name\":\"block\",\"wall_ms\":10.0,\"self_ms\":4.0}\n",
                "{\"seq\":2,\"ts_ms\":3,\"kind\":\"block_done\",\"layer\":0,\"status\":\"optimized\",\"initial_loss\":1.0,\"final_loss\":0.5,\"steps\":8,\"wall_ms\":10.0}\n",
            ),
        )
        .unwrap();
        let s = render_summary(&dir).unwrap();
        assert!(s.contains("fingerprint=00ab"), "{s}");
        assert!(s.contains("Per-phase self-time profile"), "{s}");
        assert!(s.contains("Per-block reconstruction loss"), "{s}");
        assert!(s.contains("block"), "{s}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_line_is_an_error() {
        let dir = std::env::temp_dir().join(format!("tsq_sum_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("trace.jsonl"), "{not json}\n").unwrap();
        assert!(render_summary(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
