//! Hierarchical RAII spans.
//!
//! `enter("block", Some("3"))` (or the [`crate::span!`] macro) pushes a
//! frame on a thread-local stack and emits `span_open`; dropping the
//! guard emits `span_close` with the span's wall time and *self* time —
//! wall minus the wall time of its direct children — which is what the
//! `trace-summary` profile aggregates. Parent/child structure is
//! per-thread (ids are globally unique), matching the engine's scoped
//! worker threads.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::obs::sink::{enabled, event, Val};

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

struct Frame {
    id: u64,
    /// Accumulated wall time of completed direct children, ns.
    child_ns: u128,
}

thread_local! {
    static STACK: RefCell<Vec<Frame>> = const { RefCell::new(Vec::new()) };
}

/// Open a span. Returns an inert guard (no allocation, no push) when the
/// sink is disabled.
pub fn enter(name: &'static str, detail: Option<String>) -> SpanGuard {
    if !enabled() {
        return SpanGuard { id: 0, name, detail: None, start: None };
    }
    let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
    let parent = STACK.with(|s| {
        let mut s = s.borrow_mut();
        let parent = s.last().map(|f| f.id);
        s.push(Frame { id, child_ns: 0 });
        parent
    });
    let mut fields: Vec<(&str, Val)> = vec![("id", id.into()), ("name", name.into())];
    if let Some(p) = parent {
        fields.push(("parent", p.into()));
    }
    if let Some(d) = &detail {
        fields.push(("detail", d.as_str().into()));
    }
    event("span_open", &fields);
    SpanGuard { id, name, detail, start: Some(Instant::now()) }
}

/// RAII guard returned by [`enter`]; closes the span on drop.
pub struct SpanGuard {
    id: u64,
    name: &'static str,
    detail: Option<String>,
    /// `None` = inert guard (sink was disabled at enter time).
    start: Option<Instant>,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let wall_ns = start.elapsed().as_nanos();
        let child_ns = STACK.with(|s| {
            let mut s = s.borrow_mut();
            // pop back to our frame: tolerate guards dropped out of order
            let mut child_ns = 0u128;
            while let Some(f) = s.pop() {
                if f.id == self.id {
                    child_ns = f.child_ns;
                    break;
                }
            }
            if let Some(parent) = s.last_mut() {
                parent.child_ns += wall_ns;
            }
            child_ns
        });
        let self_ns = wall_ns.saturating_sub(child_ns);
        let mut fields: Vec<(&str, Val)> = vec![
            ("id", self.id.into()),
            ("name", self.name.into()),
            ("wall_ms", (wall_ns as f64 / 1e6).into()),
            ("self_ms", (self_ns as f64 / 1e6).into()),
        ];
        if let Some(d) = self.detail.take() {
            fields.push(("detail", d.into()));
        }
        event("span_close", &fields);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inert_guard_when_disabled() {
        assert!(!enabled());
        let g = enter("noop", None);
        assert!(g.start.is_none());
        drop(g);
        STACK.with(|s| assert!(s.borrow().is_empty()));
    }
}
