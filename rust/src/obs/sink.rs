//! The JSONL event sink: one event per line, atomically appended.
//!
//! Events are written with a single `write_all` on a file opened in
//! append mode, so concurrent writers (the engine's worker threads, the
//! calibration loop) interleave whole lines, never partial ones. The
//! sink is global — a process traces to at most one directory — and
//! guarded by a mutex; the fast path for a disabled sink is one relaxed
//! atomic load and no allocation.
//!
//! Alongside `trace.jsonl` the sink maintains `manifest.json`, a
//! `{"runs": [...]}` document appended to (atomically, via tmp+rename)
//! on every [`run_start`], tying trace events to the checkpoint config
//! fingerprint so a kill@block + `--resume` pair is recognizably one
//! logical run split across two processes.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::{Context, Result};

use crate::util::json::{escape, Json};

/// One event field value. `From` impls keep call sites terse:
/// `("layer", l.into())`.
#[derive(Debug, Clone)]
pub enum Val {
    Str(String),
    F(f64),
    I(i64),
    U(u64),
    B(bool),
}

impl From<&str> for Val {
    fn from(s: &str) -> Val {
        Val::Str(s.to_string())
    }
}
impl From<String> for Val {
    fn from(s: String) -> Val {
        Val::Str(s)
    }
}
impl From<f64> for Val {
    fn from(v: f64) -> Val {
        Val::F(v)
    }
}
impl From<f32> for Val {
    fn from(v: f32) -> Val {
        Val::F(v as f64)
    }
}
impl From<usize> for Val {
    fn from(v: usize) -> Val {
        Val::U(v as u64)
    }
}
impl From<u64> for Val {
    fn from(v: u64) -> Val {
        Val::U(v)
    }
}
impl From<u32> for Val {
    fn from(v: u32) -> Val {
        Val::U(v as u64)
    }
}
impl From<i64> for Val {
    fn from(v: i64) -> Val {
        Val::I(v)
    }
}
impl From<bool> for Val {
    fn from(v: bool) -> Val {
        Val::B(v)
    }
}

impl Val {
    fn write_json(&self, out: &mut String) {
        match self {
            Val::Str(s) => {
                out.push('"');
                out.push_str(&escape(s));
                out.push('"');
            }
            Val::F(v) => out.push_str(&fmt_f64(*v)),
            Val::I(v) => out.push_str(&v.to_string()),
            Val::U(v) => out.push_str(&v.to_string()),
            Val::B(v) => out.push_str(if *v { "true" } else { "false" }),
        }
    }
}

/// JSON has no NaN/Inf; serialize non-finite floats as null so every
/// emitted line stays parseable.
pub fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

struct SinkState {
    file: File,
    dir: PathBuf,
    seq: u64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<SinkState>> = Mutex::new(None);

/// Is the sink armed? The one-branch gate every instrumentation site
/// (and any caller assembling expensive fields) should check first.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Arm the sink: create `dir`, open `dir/trace.jsonl` in append mode
/// (a resumed run extends the prior trace), and emit `telemetry_init`.
pub fn init(dir: impl Into<PathBuf>) -> Result<()> {
    let dir = dir.into();
    std::fs::create_dir_all(&dir)
        .with_context(|| format!("creating trace dir {}", dir.display()))?;
    let path = dir.join("trace.jsonl");
    let file = OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .with_context(|| format!("opening {}", path.display()))?;
    {
        let mut g = SINK.lock().unwrap_or_else(|p| p.into_inner());
        *g = Some(SinkState { file, dir, seq: 0 });
    }
    ENABLED.store(true, Ordering::SeqCst);
    event("telemetry_init", &[("pid", (std::process::id() as u64).into())]);
    Ok(())
}

/// Arm the sink from `TESSERAQ_TRACE`, if set. Used by binaries that
/// have no `--trace-out` flag of their own (benches, examples).
pub fn init_from_env() -> Result<bool> {
    match std::env::var("TESSERAQ_TRACE") {
        Ok(d) if !d.is_empty() => {
            init(d)?;
            Ok(true)
        }
        _ => Ok(false),
    }
}

/// Flush pending metrics and disarm the sink. Idempotent.
pub fn shutdown() {
    if !enabled() {
        return;
    }
    crate::obs::metrics::flush_metrics();
    ENABLED.store(false, Ordering::SeqCst);
    let mut g = SINK.lock().unwrap_or_else(|p| p.into_inner());
    *g = None;
}

/// The active trace directory, if armed.
pub fn trace_dir() -> Option<PathBuf> {
    let g = SINK.lock().unwrap_or_else(|p| p.into_inner());
    g.as_ref().map(|s| s.dir.clone())
}

fn now_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Emit one event line. No-op (one atomic load) when the sink is off.
pub fn event(kind: &str, fields: &[(&str, Val)]) {
    if !enabled() {
        return;
    }
    let mut body = String::with_capacity(96);
    for (k, v) in fields {
        body.push_str(",\"");
        body.push_str(&escape(k));
        body.push_str("\":");
        v.write_json(&mut body);
    }
    let mut g = SINK.lock().unwrap_or_else(|p| p.into_inner());
    if let Some(s) = g.as_mut() {
        let line = format!(
            "{{\"seq\":{},\"ts_ms\":{},\"kind\":\"{}\"{}}}\n",
            s.seq,
            now_ms(),
            escape(kind),
            body
        );
        s.seq += 1;
        // single write_all on an O_APPEND fd: whole-line atomicity
        let _ = s.file.write_all(line.as_bytes());
    }
}

/// Structured event + human-readable stderr line. This is the
/// replacement for the ad-hoc `eprintln!` progress prints: the pretty
/// text always reaches stderr (the human subscriber), and when the sink
/// is armed the same information lands in the trace with `msg` plus the
/// structured fields.
pub fn warn(kind: &str, msg: &str, fields: &[(&str, Val)]) {
    eprintln!("{msg}");
    if !enabled() {
        return;
    }
    let mut all: Vec<(&str, Val)> = Vec::with_capacity(fields.len() + 1);
    all.push(("msg", msg.into()));
    all.extend(fields.iter().cloned());
    event(kind, &all);
}

/// Record the start of a logical run: a `run_start` event plus an entry
/// in `manifest.json` keyed by the checkpoint config fingerprint. Both
/// halves of a kill + resume pair call this with the same fingerprint.
pub fn run_start(fingerprint: u64, method: &str, fields: &[(&str, Val)]) {
    if !enabled() {
        return;
    }
    let fp = format!("{fingerprint:016x}");
    let mut all: Vec<(&str, Val)> = vec![
        ("fingerprint", fp.as_str().into()),
        ("method", method.into()),
    ];
    all.extend(fields.iter().cloned());
    event("run_start", &all);
    if let Some(dir) = trace_dir() {
        if let Err(e) = append_manifest(&dir, &fp, method, fields) {
            eprintln!("[obs] manifest update failed: {e:#}");
        }
    }
}

fn append_manifest(dir: &Path, fp: &str, method: &str, fields: &[(&str, Val)]) -> Result<()> {
    let path = dir.join("manifest.json");
    let mut root = match std::fs::read_to_string(&path) {
        Ok(text) => Json::parse(&text).unwrap_or(Json::Null),
        Err(_) => Json::Null,
    };
    if !matches!(root, Json::Obj(_)) {
        root = Json::Obj(std::collections::BTreeMap::new());
    }
    let mut entry = std::collections::BTreeMap::new();
    entry.insert("fingerprint".to_string(), Json::Str(fp.to_string()));
    entry.insert("method".to_string(), Json::Str(method.to_string()));
    entry.insert("ts_ms".to_string(), Json::Num(now_ms() as f64));
    for (k, v) in fields {
        let jv = match v {
            Val::Str(s) => Json::Str(s.clone()),
            Val::F(x) => Json::Num(*x),
            Val::I(x) => Json::Num(*x as f64),
            Val::U(x) => Json::Num(*x as f64),
            Val::B(b) => Json::Bool(*b),
        };
        entry.insert((*k).to_string(), jv);
    }
    if let Json::Obj(m) = &mut root {
        let runs = m.entry("runs".to_string()).or_insert_with(|| Json::Arr(Vec::new()));
        if let Json::Arr(a) = runs {
            a.push(Json::Obj(entry));
        }
    }
    // atomic rewrite, same pattern as the checkpoint store
    let tmp = dir.join(".manifest.json.tmp");
    std::fs::write(&tmp, root.dump())
        .with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_f64_is_json_safe() {
        assert_eq!(fmt_f64(1.5), "1.5");
        assert_eq!(fmt_f64(f64::NAN), "null");
        assert_eq!(fmt_f64(f64::INFINITY), "null");
        assert_eq!(fmt_f64(-0.25), "-0.25");
    }

    #[test]
    fn disabled_sink_is_inert() {
        // no init in this test binary: event/warn must be no-ops
        assert!(!enabled());
        event("noop", &[("k", 1usize.into())]);
        assert!(trace_dir().is_none());
    }
}
