//! Synthetic data substrate (DESIGN.md §2): seeded corpora standing in
//! for WikiText2/C4 and likelihood-ranking tasks standing in for the five
//! zero-shot benchmarks.

pub mod corpus;
pub mod tasks;

pub use corpus::{Corpus, CorpusKind};
pub use tasks::{Task, TaskItem, TaskKind};
