//! Synthetic zero-shot tasks: likelihood ranking over candidate
//! continuations, the same readout lm_eval uses for PiQA/ARC/HellaSwag/
//! WinoGrande. Five presets of graded difficulty (continuation length,
//! distractor closeness) stand in for the paper's five benchmarks.

use crate::data::corpus::Corpus;
use crate::tensor::Pcg32;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// PiQA stand-in: long continuation, random distractor (easiest).
    PiqaS,
    /// ARC-easy stand-in: medium continuation, random distractor.
    ArcES,
    /// ARC-challenge stand-in: short continuation, shuffled distractor.
    ArcCS,
    /// HellaSwag stand-in: medium continuation, corpus-sampled distractor.
    HellaS,
    /// WinoGrande stand-in: two-token continuation, near-miss distractor.
    WinoS,
}

pub const ALL_TASKS: [TaskKind; 5] =
    [TaskKind::PiqaS, TaskKind::ArcES, TaskKind::ArcCS, TaskKind::HellaS, TaskKind::WinoS];

impl TaskKind {
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::PiqaS => "PiQA-s",
            TaskKind::ArcES => "ArcE-s",
            TaskKind::ArcCS => "ArcC-s",
            TaskKind::HellaS => "Hella-s",
            TaskKind::WinoS => "Wino-s",
        }
    }

    fn cont_len(&self) -> usize {
        match self {
            TaskKind::PiqaS => 12,
            TaskKind::ArcES => 8,
            TaskKind::ArcCS => 4,
            TaskKind::HellaS => 6,
            TaskKind::WinoS => 2,
        }
    }

    fn seed(&self) -> u64 {
        match self {
            TaskKind::PiqaS => 11,
            TaskKind::ArcES => 22,
            TaskKind::ArcCS => 33,
            TaskKind::HellaS => 44,
            TaskKind::WinoS => 55,
        }
    }
}

/// One two-way item: shared prefix, two candidate continuations, and the
/// index (0/1) of the correct one.
#[derive(Debug, Clone)]
pub struct TaskItem {
    pub prefix: Vec<i32>,
    pub cand: [Vec<i32>; 2],
    pub label: usize,
}

pub struct Task {
    pub kind: TaskKind,
    pub items: Vec<TaskItem>,
}

impl Task {
    /// Generate `n` items against a corpus (the "world" whose grammar the
    /// model has learned).
    pub fn generate(kind: TaskKind, corpus: &Corpus, n: usize, prefix_len: usize) -> Task {
        let mut rng = Pcg32::new(kind.seed(), 0xDEAD);
        let cl = kind.cont_len();
        let mut items = Vec::with_capacity(n);
        for i in 0..n {
            let prefix = corpus.sample(prefix_len, 1_000_000 + i as u64);
            let prev = prefix[prefix.len() - 2] as usize;
            let last = *prefix.last().unwrap() as usize;
            // correct continuation follows the corpus pair-transition graph
            let good = corpus.sample_continuation2(prev, last, cl, 2_000_000 + i as u64);
            let bad = match kind {
                TaskKind::PiqaS | TaskKind::ArcES => {
                    // uniform random tokens
                    (0..cl).map(|_| rng.below(corpus.vocab) as i32).collect::<Vec<_>>()
                }
                TaskKind::ArcCS => {
                    // shuffled copy of the correct continuation (harder:
                    // same unigram stats, broken transitions)
                    let mut b = good.clone();
                    rng.shuffle(&mut b);
                    if b == good {
                        b.reverse();
                    }
                    b
                }
                TaskKind::HellaS => {
                    // fluent corpus text from a different context
                    // (plausible but detached from the prefix)
                    let p0 = rng.below(corpus.vocab);
                    let c0 = rng.below(corpus.vocab);
                    corpus.sample_continuation2(p0, c0, cl, 3_000_000 + i as u64)
                }
                TaskKind::WinoS => {
                    // near-miss: correct continuation with one token swapped
                    let mut b = good.clone();
                    let j = rng.below(cl);
                    b[j] = rng.below(corpus.vocab) as i32;
                    if b == good {
                        b[j] = ((b[j] + 1) as usize % corpus.vocab) as i32;
                    }
                    b
                }
            };
            let label = rng.below(2);
            let cand = if label == 0 { [good, bad] } else { [bad, good] };
            items.push(TaskItem { prefix, cand, label });
        }
        Task { kind, items }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusKind;

    #[test]
    fn items_are_deterministic_and_balanced() {
        let corpus = Corpus::new(CorpusKind::C4Like, 128);
        let t1 = Task::generate(TaskKind::ArcES, &corpus, 100, 16);
        let t2 = Task::generate(TaskKind::ArcES, &corpus, 100, 16);
        for (a, b) in t1.items.iter().zip(&t2.items) {
            assert_eq!(a.prefix, b.prefix);
            assert_eq!(a.label, b.label);
        }
        let ones = t1.items.iter().filter(|i| i.label == 1).count();
        assert!(ones > 25 && ones < 75, "labels unbalanced: {ones}/100");
    }

    #[test]
    fn candidates_differ_and_have_right_length() {
        let corpus = Corpus::new(CorpusKind::WikiLike, 128);
        for kind in ALL_TASKS {
            let t = Task::generate(kind, &corpus, 20, 16);
            for item in &t.items {
                assert_eq!(item.cand[0].len(), kind.cont_len());
                assert_eq!(item.cand[1].len(), kind.cont_len());
                assert_ne!(item.cand[0], item.cand[1], "{:?}", kind);
            }
        }
    }

    /// An oracle scorer (the corpus's own transition log-probs) must get
    /// high accuracy — i.e. the tasks are actually solvable.
    #[test]
    fn tasks_solvable_by_oracle() {
        let corpus = Corpus::new(CorpusKind::WikiLike, 128);
        for kind in [TaskKind::PiqaS, TaskKind::ArcCS] {
            let t = Task::generate(kind, &corpus, 100, 12);
            let mut correct = 0;
            for item in &t.items {
                let score = |cand: &[i32]| -> f64 {
                    let mut prev = item.prefix[item.prefix.len() - 2] as usize;
                    let mut cur = *item.prefix.last().unwrap() as usize;
                    let mut lp = 0.0;
                    for &tok in cand {
                        lp += corpus.transition_logprob2(prev, cur, tok as usize);
                        prev = cur;
                        cur = tok as usize;
                    }
                    lp
                };
                let pick = if score(&item.cand[0]) >= score(&item.cand[1]) { 0 } else { 1 };
                if pick == item.label {
                    correct += 1;
                }
            }
            assert!(correct >= 80, "{:?}: oracle only {correct}/100", kind);
        }
    }
}
