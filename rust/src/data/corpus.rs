//! Seeded synthetic corpora with learnable n-gram structure.
//!
//! Each corpus is a sparse **second-order** Markov chain: the successor
//! distribution depends on the (prev, cur) token pair, so a model must
//! route information through attention (not just the embedding-unigram
//! shortcut) to reach low perplexity. That keeps the trained model
//! capacity-stressed, which is what makes low-bit quantization damage
//! measurable — the regime the paper's LLaMA results live in.
//!
//! Pair-conditional successor sets are derived on the fly from a hash of
//! the pair (no V^2 table), with Zipfian weights, an epsilon of uniform
//! noise, and a sentence-boundary token that resets context.
//!
//! Two presets with different topology/temperature stand in for
//! WikiText2 and C4 — distinct enough that cross-dataset calibration
//! shows the Table 5 effect (calibrating on A helps eval on A).

use crate::tensor::Pcg32;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorpusKind {
    /// WikiText2 stand-in: narrower successor sets, lower entropy.
    WikiLike,
    /// C4 stand-in: broader successor sets, higher entropy, other seed.
    C4Like,
}

impl CorpusKind {
    pub fn name(&self) -> &'static str {
        match self {
            CorpusKind::WikiLike => "wiki-like",
            CorpusKind::C4Like => "c4-like",
        }
    }

    fn seed(&self) -> u64 {
        match self {
            CorpusKind::WikiLike => 0x5EED_0001,
            CorpusKind::C4Like => 0x5EED_0002,
        }
    }

    fn branching(&self) -> usize {
        match self {
            CorpusKind::WikiLike => 4,
            CorpusKind::C4Like => 7,
        }
    }

    fn noise(&self) -> f64 {
        match self {
            CorpusKind::WikiLike => 0.02,
            CorpusKind::C4Like => 0.05,
        }
    }
}

pub struct Corpus {
    pub kind: CorpusKind,
    pub vocab: usize,
    start_dist: Vec<f64>,
    period: usize,
}

impl Corpus {
    pub fn new(kind: CorpusKind, vocab: usize) -> Corpus {
        assert!(vocab >= 16);
        let mut rng = Pcg32::new(kind.seed(), vocab as u64);
        let period = vocab - 1; // sentence boundary token
        let start_dist: Vec<f64> = (0..vocab).map(|_| rng.uniform() + 0.1).collect();
        Corpus { kind, vocab, start_dist, period }
    }

    /// Deterministic successor set + Zipf weights for a (prev, cur) pair.
    ///
    /// Half the contexts (even `cur`) are first-order — quickly learnable
    /// from the embedding alone — and half are genuinely second-order,
    /// requiring attention. The mix keeps pretraining fast while leaving
    /// the trained model capacity-stressed enough that low-bit
    /// quantization damage is measurable.
    fn successors(&self, prev: usize, cur: usize) -> (Vec<usize>, Vec<f64>) {
        let b = self.kind.branching();
        let pair = if cur % 2 == 0 {
            cur as u64
        } else {
            (prev as u64) << 20 | cur as u64
        };
        let mut prng = Pcg32::new(self.kind.seed() ^ 0x9E3779B97F4A7C15, pair);
        let mut succ = Vec::with_capacity(b + 1);
        let mut w = Vec::with_capacity(b + 1);
        for r in 0..b {
            succ.push(prng.below(self.vocab));
            w.push(1.0 / (r + 1) as f64); // Zipfian
        }
        succ.push(self.period);
        w.push(0.08);
        (succ, w)
    }

    /// One transition given (prev, cur) context.
    pub fn step2(&self, prev: usize, cur: usize, rng: &mut Pcg32) -> usize {
        if cur == self.period {
            rng.weighted(&self.start_dist)
        } else if rng.uniform() < self.kind.noise() {
            rng.below(self.vocab)
        } else {
            let (succ, w) = self.successors(prev, cur);
            succ[rng.weighted(&w)]
        }
    }

    /// Back-compat first-order step (uses period as a neutral prev).
    pub fn step(&self, cur: usize, rng: &mut Pcg32) -> usize {
        self.step2(self.period, cur, rng)
    }

    /// log P(to | prev, cur) under the generator (oracle scorer).
    pub fn transition_logprob2(&self, prev: usize, cur: usize, to: usize) -> f64 {
        let noise = self.kind.noise();
        let uniform = noise / self.vocab as f64;
        if cur == self.period {
            let total: f64 = self.start_dist.iter().sum();
            return (self.start_dist[to] / total).ln();
        }
        let (succ, w) = self.successors(prev, cur);
        let total: f64 = w.iter().sum();
        let mut p = uniform;
        for (s, &wt) in succ.iter().zip(&w) {
            if *s == to {
                p += (1.0 - noise) * wt / total;
            }
        }
        p.max(1e-12).ln()
    }

    pub fn transition_logprob(&self, from: usize, to: usize) -> f64 {
        self.transition_logprob2(self.period, from, to)
    }

    /// Sample a stream of `len` tokens, deterministic per (corpus, seed).
    pub fn sample(&self, len: usize, seed: u64) -> Vec<i32> {
        let mut rng = Pcg32::new(self.kind.seed() ^ 0xABCD, seed);
        let mut out = Vec::with_capacity(len);
        let mut prev = self.period;
        let mut cur = rng.weighted(&self.start_dist);
        for _ in 0..len {
            out.push(cur as i32);
            let next = self.step2(prev, cur, &mut rng);
            prev = cur;
            cur = next;
        }
        out
    }

    /// `n` sequences of length `t` as a flat [n * t] token buffer.
    pub fn sequences(&self, n: usize, t: usize, seed: u64) -> Vec<i32> {
        let mut out = Vec::with_capacity(n * t);
        for i in 0..n {
            out.extend(self.sample(t, seed.wrapping_add(i as u64 * 7919)));
        }
        out
    }

    /// Stochastic continuation following the pair-transition graph.
    pub fn sample_continuation2(
        &self,
        prev: usize,
        cur: usize,
        len: usize,
        seed: u64,
    ) -> Vec<i32> {
        let mut rng = Pcg32::new(seed, 0xC0FFEE);
        let (mut p, mut c) = (prev, cur);
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            let next = self.step2(p, c, &mut rng);
            out.push(next as i32);
            p = c;
            c = next;
        }
        out
    }

    pub fn sample_continuation(&self, start: usize, len: usize, seed: u64) -> Vec<i32> {
        self.sample_continuation2(self.period, start, len, seed)
    }

    /// Most likely continuation (greedy through the pair graph).
    pub fn greedy_continuation(&self, start: usize, len: usize) -> Vec<i32> {
        let (mut p, mut c) = (self.period, start);
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            let (succ, w) = self.successors(p, c);
            let mut best = (start, f64::NEG_INFINITY);
            for (s, &wt) in succ.iter().zip(&w) {
                if wt > best.1 && *s != self.period {
                    best = (*s, wt);
                }
            }
            p = c;
            c = best.0;
            out.push(c as i32);
        }
        out
    }

    pub fn period_token(&self) -> usize {
        self.period
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_sampling() {
        let c = Corpus::new(CorpusKind::WikiLike, 128);
        assert_eq!(c.sample(100, 1), c.sample(100, 1));
        assert_ne!(c.sample(100, 1), c.sample(100, 2));
    }

    #[test]
    fn corpora_differ() {
        let a = Corpus::new(CorpusKind::WikiLike, 128);
        let b = Corpus::new(CorpusKind::C4Like, 128);
        assert_ne!(a.sample(64, 0), b.sample(64, 0));
    }

    #[test]
    fn tokens_in_range() {
        let c = Corpus::new(CorpusKind::C4Like, 256);
        assert!(c.sample(5000, 3).iter().all(|&t| (t as usize) < 256));
    }

    /// The corpus must be genuinely second-order: trigram conditional
    /// entropy well below bigram conditional entropy, both far below
    /// ln(V). This is what forces the LM to use attention.
    #[test]
    fn corpus_is_second_order() {
        let v = 64; // small vocab so counts converge quickly
        let c = Corpus::new(CorpusKind::WikiLike, v);
        let toks = c.sample(400_000, 0);
        // bigram H(next | cur)
        let mut big = vec![0f64; v * v];
        let mut m1 = vec![0f64; v];
        for w in toks.windows(2) {
            big[w[0] as usize * v + w[1] as usize] += 1.0;
            m1[w[0] as usize] += 1.0;
        }
        let total = (toks.len() - 1) as f64;
        let mut h1 = 0.0;
        for a in 0..v {
            for b in 0..v {
                let cnt = big[a * v + b];
                if cnt > 0.0 {
                    h1 -= (cnt / total) * (cnt / m1[a]).ln();
                }
            }
        }
        // trigram H(next | prev, cur) via hashmap
        use std::collections::HashMap;
        let mut tri: HashMap<(i32, i32, i32), f64> = HashMap::new();
        let mut m2: HashMap<(i32, i32), f64> = HashMap::new();
        for w in toks.windows(3) {
            *tri.entry((w[0], w[1], w[2])).or_default() += 1.0;
            *m2.entry((w[0], w[1])).or_default() += 1.0;
        }
        let t3 = (toks.len() - 2) as f64;
        let mut h2 = 0.0;
        for ((a, b, cc), cnt) in &tri {
            let denom = m2[&(*a, *b)];
            h2 -= (cnt / t3) * (cnt / denom).ln();
            let _ = cc;
        }
        let max_h = (v as f64).ln();
        assert!(h2 < 0.75 * h1, "not second-order: H2 {h2:.2} vs H1 {h1:.2}");
        assert!(h2 < 0.6 * max_h, "trigram entropy too high: {h2:.2}");
        assert!(h2 > 0.3, "degenerate corpus");
    }

    #[test]
    fn sequences_shape() {
        let c = Corpus::new(CorpusKind::WikiLike, 128);
        let s = c.sequences(4, 64, 0);
        assert_eq!(s.len(), 4 * 64);
    }

    #[test]
    fn greedy_continuation_avoids_period() {
        let c = Corpus::new(CorpusKind::WikiLike, 128);
        let cont = c.greedy_continuation(5, 20);
        assert!(cont.iter().all(|&t| t as usize != c.period_token()));
    }

    #[test]
    fn pair_successors_are_stable() {
        let c = Corpus::new(CorpusKind::WikiLike, 128);
        assert_eq!(c.successors(3, 7), c.successors(3, 7));
        assert_ne!(c.successors(3, 7).0, c.successors(4, 7).0);
    }
}
