//! TesseraQ reproduction — L3 coordinator library.
//!
//! Three-layer architecture (see DESIGN.md):
//! - L1: Pallas kernels (python/compile/kernels, build-time only)
//! - L2: JAX graphs lowered to HLO text artifacts (python/compile)
//! - L3: this crate — loads `artifacts/*.hlo.txt` on the PJRT CPU client
//!   and runs the paper's calibration pipeline, baselines, evaluation
//!   harness and quantized serving path. Python never runs at runtime.

// The code favors explicit index loops where they mirror the paper's math
// (and the Python reference); keep clippy focused on correctness lints.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_memcpy,
    clippy::type_complexity
)]

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod model;
pub mod obs;
pub mod quant;
pub mod report;
pub mod robust;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;

pub use runtime::Engine;
pub use tensor::Tensor;

/// Default artifact directory relative to the repo root.
pub fn default_artifact_dir() -> std::path::PathBuf {
    // Allow override for tests / deployments.
    if let Ok(d) = std::env::var("TESSERAQ_ARTIFACTS") {
        return d.into();
    }
    // Walk up from cwd until we find artifacts/manifest.json.
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
