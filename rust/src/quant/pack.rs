//! INT{2,3,4} bit-packing and the packed dequant-matmul serving kernel.
//!
//! Layout contract (shared with python/compile/kernels/qmatmul.py and
//! kernels/ref.py::unpack_codes_ref): codes run along the input dimension,
//! `per_word = 32 / bits` codes per u32 word, code j in bits
//! [bits*j, bits*(j+1)) of its word (low bits first). bits=3 packs 10
//! codes per word, wasting the top 2 bits.

use anyhow::{bail, Result};

use crate::model::hostfwd::LinearOp;
use crate::quant::QParams;
use crate::tensor::{linalg, Tensor};
use crate::util::parallel_chunks;

#[derive(Debug, Clone)]
pub struct PackedLinear {
    pub bits: u32,
    pub out_features: usize,
    pub in_features: usize,
    /// [out, n_words] packed codes.
    pub words: Vec<u32>,
    pub n_words: usize,
    pub qp: QParams,
}

pub fn per_word(bits: u32) -> usize {
    (32 / bits) as usize
}

pub fn pack_codes(codes: &[u16], o: usize, i: usize, bits: u32) -> (Vec<u32>, usize) {
    let pw = per_word(bits);
    let nw = i.div_ceil(pw);
    let mut words = vec![0u32; o * nw];
    let mask = (1u32 << bits) - 1;
    for r in 0..o {
        for c in 0..i {
            let code = codes[r * i + c] as u32 & mask;
            words[r * nw + c / pw] |= code << (bits as usize * (c % pw));
        }
    }
    (words, nw)
}

pub fn unpack_codes(words: &[u32], o: usize, i: usize, bits: u32) -> Vec<u16> {
    let pw = per_word(bits);
    let nw = i.div_ceil(pw);
    let mask = (1u32 << bits) - 1;
    let mut codes = vec![0u16; o * i];
    for r in 0..o {
        for c in 0..i {
            let w = words[r * nw + c / pw];
            codes[r * i + c] = ((w >> (bits as usize * (c % pw))) & mask) as u16;
        }
    }
    codes
}

impl PackedLinear {
    pub fn from_codes(codes: &[u16], o: usize, i: usize, bits: u32, qp: QParams) -> Result<Self> {
        if !(1..=16).contains(&bits) {
            bail!("packed bits must be in 1..=16, got {bits}");
        }
        if codes.len() != o * i {
            bail!("got {} codes for a [{o}, {i}] weight (want {})", codes.len(), o * i);
        }
        if let Some(pos) = codes.iter().position(|&c| (c as u32) >= (1 << bits)) {
            bail!(
                "code {} at [{}, {}] overflows {bits}-bit range",
                codes[pos],
                pos / i,
                pos % i
            );
        }
        let (words, n_words) = pack_codes(codes, o, i, bits);
        Ok(PackedLinear { bits, out_features: o, in_features: i, words, n_words, qp })
    }

    /// Dequantize to a dense f32 weight (testing / fallback).
    pub fn dequant_dense(&self) -> Tensor {
        let codes = unpack_codes(&self.words, self.out_features, self.in_features, self.bits);
        crate::quant::dequant_codes(&codes, self.out_features, self.in_features, &self.qp)
    }

    /// Decode packed weight row `j` into `out[..in_features]`.
    ///
    /// This is the serving kernel's inner decode: each u32 word is loaded
    /// once and its `per_word` codes peeled off by shifting the register
    /// (no per-code word/offset division), and the group scale/zero pair
    /// is re-read only at group boundaries, not per code.
    #[inline]
    pub fn dequant_row_into(&self, j: usize, out: &mut [f32]) {
        let k = self.in_features;
        debug_assert!(out.len() >= k);
        if k == 0 {
            return;
        }
        let bits = self.bits;
        let pw = per_word(bits);
        let mask = (1u32 << bits) - 1;
        let g = self.qp.group;
        let ng = self.qp.n_groups();
        let srow = &self.qp.s.data[j * ng..(j + 1) * ng];
        let zrow = &self.qp.z.data[j * ng..(j + 1) * ng];
        let wrow = &self.words[j * self.n_words..(j + 1) * self.n_words];
        let mut gi = 0usize;
        let mut s = srow[0];
        let mut z = zrow[0];
        let mut next_edge = g.min(k);
        let mut widx = 0usize;
        let mut word = wrow[0];
        let mut left = pw;
        for (c, o) in out[..k].iter_mut().enumerate() {
            if c == next_edge {
                gi += 1;
                s = srow[gi];
                z = zrow[gi];
                next_edge = ((gi + 1) * g).min(k);
            }
            *o = s * ((word & mask) as f32 - z);
            word >>= bits;
            left -= 1;
            if left == 0 {
                widx += 1;
                if widx < wrow.len() {
                    word = wrow[widx];
                }
                left = pw;
            }
        }
    }
}

impl LinearOp for PackedLinear {
    fn out_features(&self) -> usize {
        self.out_features
    }

    fn in_features(&self) -> usize {
        self.in_features
    }

    /// Fused unpack + dequant + matvec/matmul: y = x @ dequant(W).T.
    ///
    /// Weight-stationary and memory-bound like the paper's Exllama/Triton
    /// kernels: each worker owns a contiguous cache block of output rows,
    /// decodes each packed row exactly once into a per-worker scratch
    /// buffer (`dequant_row_into` — whole-word decode, group lookups
    /// hoisted), and runs the unrolled dot against every input row while
    /// the decoded weights are still hot.
    fn forward(&self, x: &Tensor) -> Tensor {
        let (m, k) = x.dims2();
        assert_eq!(k, self.in_features);
        let mut out = vec![0.0f32; m * self.out_features];
        self.forward_into(&x.data, m, &mut out);
        Tensor::new(vec![m, self.out_features], out)
    }

    fn forward_into(&self, x: &[f32], m: usize, out: &mut [f32]) {
        let k = self.in_features;
        let o = self.out_features;
        assert_eq!(x.len(), m * k, "x len vs [{m}, {k}]");
        assert_eq!(out.len(), m * o, "out len vs [{m}, {o}]");
        if m == 1 {
            // Matvec (the decode step): out is already the [o] column, no
            // transpose needed.
            let out_ptr = out.as_ptr() as usize;
            parallel_chunks(o, |_, s0, e0| {
                let ov = unsafe { std::slice::from_raw_parts_mut(out_ptr as *mut f32, o) };
                let mut wdeq = vec![0.0f32; k];
                for j in s0..e0 {
                    self.dequant_row_into(j, &mut wdeq);
                    ov[j] = linalg::dot_unrolled(x, &wdeq);
                }
            });
            return;
        }
        // Batched: accumulate transposed [o, m] so each decoded weight row
        // writes one contiguous slice, then transpose back.
        let outt = vec![0.0f32; o * m];
        let outt_ptr = outt.as_ptr() as usize;
        parallel_chunks(o, |_, s0, e0| {
            let ot = unsafe { std::slice::from_raw_parts_mut(outt_ptr as *mut f32, o * m) };
            let mut wdeq = vec![0.0f32; k];
            for j in s0..e0 {
                self.dequant_row_into(j, &mut wdeq);
                let orow = &mut ot[j * m..(j + 1) * m];
                for (i, ov) in orow.iter_mut().enumerate() {
                    *ov = linalg::dot_unrolled(&x[i * k..(i + 1) * k], &wdeq);
                }
            }
        });
        for j in 0..o {
            for i in 0..m {
                out[i * o + j] = outt[j * m + i];
            }
        }
    }

    fn weight_bytes(&self) -> usize {
        self.words.len() * 4 + self.qp.s.data.len() * 4 + self.qp.z.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{minmax_scale, rtn_codes, ClipFactors};
    use crate::tensor::Pcg32;

    #[test]
    fn pack_unpack_roundtrip_all_bits() {
        let mut rng = Pcg32::seeded(0);
        for bits in [2u32, 3, 4, 8] {
            let (o, i) = (5, 37); // deliberately not word-aligned
            let codes: Vec<u16> =
                (0..o * i).map(|_| rng.below(1 << bits) as u16).collect();
            let (words, _) = pack_codes(&codes, o, i, bits);
            let got = unpack_codes(&words, o, i, bits);
            assert_eq!(got, codes, "bits={bits}");
        }
    }

    #[test]
    fn packed_forward_matches_dense() {
        let mut rng = Pcg32::seeded(1);
        for bits in [2u32, 3, 4] {
            let (o, i, g) = (24, 64, 16);
            let w = Tensor::randn(&[o, i], 1.0, &mut rng);
            let qmax = (2u32.pow(bits) - 1) as f32;
            let qp = minmax_scale(&w, g, &ClipFactors::Uniform(1.0),
                                  &ClipFactors::Uniform(1.0), qmax);
            let codes = rtn_codes(&w, &qp, qmax);
            let pl = PackedLinear::from_codes(&codes, o, i, bits, qp).unwrap();
            let x = Tensor::randn(&[7, i], 1.0, &mut rng);
            let dense = pl.dequant_dense();
            let want = dense.matmul_bt(&x);
            let got = pl.forward(&x);
            let rmse = got.mse(&want).sqrt();
            assert!(rmse < 1e-4, "bits={bits} rmse={rmse}");
        }
    }

    #[test]
    fn dequant_roundtrip_tail_columns() {
        // dequant(pack(codes)) must be bit-exact even when the input dim
        // leaves a partial final word (and a partial final group)
        let mut rng = Pcg32::seeded(3);
        for bits in [2u32, 3, 4] {
            for i in [31usize, 37, 61] {
                let o = 4;
                let g = 16.min(i);
                let w = Tensor::randn(&[o, i], 1.0, &mut rng);
                let qmax = (2u32.pow(bits) - 1) as f32;
                let qp = minmax_scale(&w, g, &ClipFactors::Uniform(1.0),
                                      &ClipFactors::Uniform(1.0), qmax);
                let codes = rtn_codes(&w, &qp, qmax);
                let want = crate::quant::dequant_codes(&codes, o, i, &qp);
                let pl = PackedLinear::from_codes(&codes, o, i, bits, qp).unwrap();
                let got = pl.dequant_dense();
                assert_eq!(got.data, want.data, "bits={bits} i={i}");
            }
        }
    }

    #[test]
    fn packed_forward_matches_dequant_dense_proptest() {
        // The fused kernel must agree with dequant-then-dense-matmul for
        // arbitrary ragged shapes: partial tail words (bits=3 packs 10
        // codes/word, so most widths leave one), partial tail groups, and
        // group edges that fall mid-word.
        crate::util::proptest(48, 0xA11CE, |rng| {
            let bits = [2u32, 3, 4][rng.below(3)];
            let o = 1 + rng.below(7);
            let i = 1 + rng.below(79);
            let g = 1 + rng.below(i);
            let ng = i.div_ceil(g);
            let m = 1 + rng.below(5);
            let codes: Vec<u16> =
                (0..o * i).map(|_| rng.below(1 << bits) as u16).collect();
            let s = Tensor::from_fn(&[o, ng], |_| 0.02 + rng.uniform() as f32);
            let z = Tensor::from_fn(&[o, ng], |_| rng.below(1 << bits) as f32);
            let qp = QParams { s, z, group: g };
            let pl = PackedLinear::from_codes(&codes, o, i, bits, qp).unwrap();
            let x = Tensor::randn(&[m, i], 1.0, rng);
            let want = pl.dequant_dense().matmul_bt(&x);
            let got = pl.forward(&x);
            assert_eq!(got.shape, want.shape);
            for (t, (a, b)) in got.data.iter().zip(&want.data).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4,
                    "bits={bits} o={o} i={i} g={g} m={m} elem {t}: {a} vs {b}"
                );
            }
        });
    }

    #[test]
    fn dequant_row_into_matches_dequant_dense() {
        // Word-at-a-time row decode must be bit-exact against the
        // reference unpack across bit widths and tail columns.
        let mut rng = Pcg32::seeded(7);
        for bits in [2u32, 3, 4] {
            for i in [10usize, 31, 37, 64] {
                let o = 3;
                let g = 16.min(i);
                let ng = i.div_ceil(g);
                let codes: Vec<u16> =
                    (0..o * i).map(|_| rng.below(1 << bits) as u16).collect();
                let s = Tensor::from_fn(&[o, ng], |_| 0.1 + rng.uniform() as f32);
                let z = Tensor::from_fn(&[o, ng], |_| rng.below(1 << bits) as f32);
                let qp = QParams { s, z, group: g };
                let pl = PackedLinear::from_codes(&codes, o, i, bits, qp).unwrap();
                let dense = pl.dequant_dense();
                let mut row = vec![0.0f32; i];
                for j in 0..o {
                    pl.dequant_row_into(j, &mut row);
                    assert_eq!(&row, &dense.data[j * i..(j + 1) * i], "bits={bits} i={i} j={j}");
                }
            }
        }
    }

    #[test]
    fn from_codes_rejects_bad_input() {
        let qp = QParams {
            s: Tensor::new(vec![1, 1], vec![1.0]),
            z: Tensor::new(vec![1, 1], vec![0.0]),
            group: 4,
        };
        // overflowing code is named with its position
        let err = PackedLinear::from_codes(&[0, 1, 4, 2], 1, 4, 2, qp.clone())
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("overflows"), "{msg}");
        // wrong code count for the declared shape
        assert!(PackedLinear::from_codes(&[0, 1, 2], 1, 4, 2, qp.clone()).is_err());
        // nonsense bit width
        assert!(PackedLinear::from_codes(&[0; 4], 1, 4, 0, qp).is_err());
    }

    #[test]
    fn weight_bytes_ratio() {
        let mut rng = Pcg32::seeded(2);
        let (o, i) = (256, 256);
        let w = Tensor::randn(&[o, i], 1.0, &mut rng);
        let qp = minmax_scale(&w, 128, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), 3.0);
        let codes = rtn_codes(&w, &qp, 3.0);
        let pl = PackedLinear::from_codes(&codes, o, i, 2, qp).unwrap();
        let fp16_bytes = o * i * 2;
        let ratio = fp16_bytes as f64 / pl.weight_bytes() as f64;
        // 2-bit + per-128 scales: close to 8x smaller than fp16
        assert!(ratio > 6.0, "compression ratio {ratio}");
    }
}
