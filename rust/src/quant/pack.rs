//! INT{2,3,4} bit-packing and the packed dequant-matmul serving kernel.
//!
//! Layout contract (shared with python/compile/kernels/qmatmul.py and
//! kernels/ref.py::unpack_codes_ref): codes run along the input dimension,
//! `per_word = 32 / bits` codes per u32 word, code j in bits
//! [bits*j, bits*(j+1)) of its word (low bits first). bits=3 packs 10
//! codes per word, wasting the top 2 bits.

use anyhow::{bail, Result};

use crate::model::hostfwd::LinearOp;
use crate::quant::QParams;
use crate::tensor::Tensor;
use crate::util::parallel_rows;

#[derive(Debug, Clone)]
pub struct PackedLinear {
    pub bits: u32,
    pub out_features: usize,
    pub in_features: usize,
    /// [out, n_words] packed codes.
    pub words: Vec<u32>,
    pub n_words: usize,
    pub qp: QParams,
}

pub fn per_word(bits: u32) -> usize {
    (32 / bits) as usize
}

pub fn pack_codes(codes: &[u16], o: usize, i: usize, bits: u32) -> (Vec<u32>, usize) {
    let pw = per_word(bits);
    let nw = i.div_ceil(pw);
    let mut words = vec![0u32; o * nw];
    let mask = (1u32 << bits) - 1;
    for r in 0..o {
        for c in 0..i {
            let code = codes[r * i + c] as u32 & mask;
            words[r * nw + c / pw] |= code << (bits as usize * (c % pw));
        }
    }
    (words, nw)
}

pub fn unpack_codes(words: &[u32], o: usize, i: usize, bits: u32) -> Vec<u16> {
    let pw = per_word(bits);
    let nw = i.div_ceil(pw);
    let mask = (1u32 << bits) - 1;
    let mut codes = vec![0u16; o * i];
    for r in 0..o {
        for c in 0..i {
            let w = words[r * nw + c / pw];
            codes[r * i + c] = ((w >> (bits as usize * (c % pw))) & mask) as u16;
        }
    }
    codes
}

impl PackedLinear {
    pub fn from_codes(codes: &[u16], o: usize, i: usize, bits: u32, qp: QParams) -> Result<Self> {
        if !(1..=16).contains(&bits) {
            bail!("packed bits must be in 1..=16, got {bits}");
        }
        if codes.len() != o * i {
            bail!("got {} codes for a [{o}, {i}] weight (want {})", codes.len(), o * i);
        }
        if let Some(pos) = codes.iter().position(|&c| (c as u32) >= (1 << bits)) {
            bail!(
                "code {} at [{}, {}] overflows {bits}-bit range",
                codes[pos],
                pos / i,
                pos % i
            );
        }
        let (words, n_words) = pack_codes(codes, o, i, bits);
        Ok(PackedLinear { bits, out_features: o, in_features: i, words, n_words, qp })
    }

    /// Dequantize to a dense f32 weight (testing / fallback).
    pub fn dequant_dense(&self) -> Tensor {
        let codes = unpack_codes(&self.words, self.out_features, self.in_features, self.bits);
        crate::quant::dequant_codes(&codes, self.out_features, self.in_features, &self.qp)
    }
}

impl LinearOp for PackedLinear {
    fn out_features(&self) -> usize {
        self.out_features
    }

    fn in_features(&self) -> usize {
        self.in_features
    }

    /// Fused unpack + dequant + matvec/matmul: y = x @ dequant(W).T.
    ///
    /// The hot loop dequantizes one weight row group-by-group into
    /// registers and runs the dot product immediately — weights are read
    /// once in packed form (memory-bound regime, like the paper's
    /// Exllama/Triton kernels).
    fn forward(&self, x: &Tensor) -> Tensor {
        let (m, k) = x.dims2();
        assert_eq!(k, self.in_features);
        let o = self.out_features;
        let bits = self.bits;
        let pw = per_word(bits);
        let mask = (1u32 << bits) - 1;
        let g = self.qp.group;
        let ng = self.qp.n_groups();
        let mut out = vec![0.0f32; m * o];
        // Parallelize over output rows (weight-stationary): each worker
        // dequantizes a weight row once and applies it to all m inputs.
        let xm = &x.data;
        let mut outt = vec![0.0f32; o * m]; // transposed accumulation
        parallel_rows(&mut outt, m, |j, orow| {
            let wrow = &self.words[j * self.n_words..(j + 1) * self.n_words];
            let mut wdeq = vec![0.0f32; k];
            for c in 0..k {
                let code = (wrow[c / pw] >> (bits as usize * (c % pw))) & mask;
                let gi = c / g;
                let s = self.qp.s.data[j * ng + gi];
                let z = self.qp.z.data[j * ng + gi];
                wdeq[c] = s * (code as f32 - z);
            }
            for (i, ov) in orow.iter_mut().enumerate() {
                let xi = &xm[i * k..(i + 1) * k];
                let mut acc = 0.0f32;
                for t in 0..k {
                    acc += xi[t] * wdeq[t];
                }
                *ov = acc;
            }
        });
        // transpose back [o, m] -> [m, o]
        for j in 0..o {
            for i in 0..m {
                out[i * o + j] = outt[j * m + i];
            }
        }
        Tensor::new(vec![m, o], out)
    }

    fn weight_bytes(&self) -> usize {
        self.words.len() * 4 + self.qp.s.data.len() * 4 + self.qp.z.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{minmax_scale, rtn_codes, ClipFactors};
    use crate::tensor::Pcg32;

    #[test]
    fn pack_unpack_roundtrip_all_bits() {
        let mut rng = Pcg32::seeded(0);
        for bits in [2u32, 3, 4, 8] {
            let (o, i) = (5, 37); // deliberately not word-aligned
            let codes: Vec<u16> =
                (0..o * i).map(|_| rng.below(1 << bits) as u16).collect();
            let (words, _) = pack_codes(&codes, o, i, bits);
            let got = unpack_codes(&words, o, i, bits);
            assert_eq!(got, codes, "bits={bits}");
        }
    }

    #[test]
    fn packed_forward_matches_dense() {
        let mut rng = Pcg32::seeded(1);
        for bits in [2u32, 3, 4] {
            let (o, i, g) = (24, 64, 16);
            let w = Tensor::randn(&[o, i], 1.0, &mut rng);
            let qmax = (2u32.pow(bits) - 1) as f32;
            let qp = minmax_scale(&w, g, &ClipFactors::Uniform(1.0),
                                  &ClipFactors::Uniform(1.0), qmax);
            let codes = rtn_codes(&w, &qp, qmax);
            let pl = PackedLinear::from_codes(&codes, o, i, bits, qp).unwrap();
            let x = Tensor::randn(&[7, i], 1.0, &mut rng);
            let dense = pl.dequant_dense();
            let want = dense.matmul_bt(&x);
            let got = pl.forward(&x);
            let rmse = got.mse(&want).sqrt();
            assert!(rmse < 1e-4, "bits={bits} rmse={rmse}");
        }
    }

    #[test]
    fn dequant_roundtrip_tail_columns() {
        // dequant(pack(codes)) must be bit-exact even when the input dim
        // leaves a partial final word (and a partial final group)
        let mut rng = Pcg32::seeded(3);
        for bits in [2u32, 3, 4] {
            for i in [31usize, 37, 61] {
                let o = 4;
                let g = 16.min(i);
                let w = Tensor::randn(&[o, i], 1.0, &mut rng);
                let qmax = (2u32.pow(bits) - 1) as f32;
                let qp = minmax_scale(&w, g, &ClipFactors::Uniform(1.0),
                                      &ClipFactors::Uniform(1.0), qmax);
                let codes = rtn_codes(&w, &qp, qmax);
                let want = crate::quant::dequant_codes(&codes, o, i, &qp);
                let pl = PackedLinear::from_codes(&codes, o, i, bits, qp).unwrap();
                let got = pl.dequant_dense();
                assert_eq!(got.data, want.data, "bits={bits} i={i}");
            }
        }
    }

    #[test]
    fn from_codes_rejects_bad_input() {
        let qp = QParams {
            s: Tensor::new(vec![1, 1], vec![1.0]),
            z: Tensor::new(vec![1, 1], vec![0.0]),
            group: 4,
        };
        // overflowing code is named with its position
        let err = PackedLinear::from_codes(&[0, 1, 4, 2], 1, 4, 2, qp.clone())
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("overflows"), "{msg}");
        // wrong code count for the declared shape
        assert!(PackedLinear::from_codes(&[0, 1, 2], 1, 4, 2, qp.clone()).is_err());
        // nonsense bit width
        assert!(PackedLinear::from_codes(&[0; 4], 1, 4, 0, qp).is_err());
    }

    #[test]
    fn weight_bytes_ratio() {
        let mut rng = Pcg32::seeded(2);
        let (o, i) = (256, 256);
        let w = Tensor::randn(&[o, i], 1.0, &mut rng);
        let qp = minmax_scale(&w, 128, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), 3.0);
        let codes = rtn_codes(&w, &qp, 3.0);
        let pl = PackedLinear::from_codes(&codes, o, i, 2, qp).unwrap();
        let fp16_bytes = o * i * 2;
        let ratio = fp16_bytes as f64 / pl.weight_bytes() as f64;
        // 2-bit + per-128 scales: close to 8x smaller than fp16
        assert!(ratio > 6.0, "compression ratio {ratio}");
    }
}
