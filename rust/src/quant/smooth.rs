//! SmoothQuant (Xiao et al.): migrate activation outliers into weights
//! via per-channel scaling s_j = max|x_j|^alpha / max|w_j|^(1-alpha),
//! folded into an equivalence-preserving carrier:
//!   q/k/v   <- carrier norm1,     gate/up <- carrier norm2,
//!   o_proj  <- carrier v_proj rows, down_proj <- carrier up_proj rows.
//! (the paper smooths linear inputs; the gated-MLP carrier for down_proj
//! works because silu(gate) is untouched while up rows scale.)

use std::collections::BTreeMap;

use crate::model::hostfwd::{block_fwd, BlockFwdOpts, Taps};
use crate::model::transform::{scale_cols, scale_rows};
use crate::model::Params;
use crate::tensor::Tensor;

/// Per-channel max|activation| from a tap matrix [rows, ch].
pub fn act_absmax(x: &Tensor) -> Vec<f32> {
    let (rows, ch) = x.dims2();
    let mut m = vec![0.0f32; ch];
    for r in 0..rows {
        for c in 0..ch {
            m[c] = m[c].max(x.data[r * ch + c].abs());
        }
    }
    m
}

/// Per-input-channel max|w| of W [out, in].
pub fn weight_col_absmax(w: &Tensor) -> Vec<f32> {
    let (o, i) = w.dims2();
    let mut m = vec![0.0f32; i];
    for r in 0..o {
        for c in 0..i {
            m[c] = m[c].max(w.data[r * i + c].abs());
        }
    }
    m
}

pub fn smooth_scales(act_max: &[f32], w_max: &[f32], alpha: f32) -> Vec<f32> {
    act_max
        .iter()
        .zip(w_max)
        .map(|(&a, &w)| {
            let s = a.max(1e-5).powf(alpha) / w.max(1e-5).powf(1.0 - alpha);
            s.clamp(1e-4, 1e4)
        })
        .collect()
}

/// Apply SmoothQuant to every block using activation taps collected by a
/// host forward pass over `calib_x` [b, t, d]. Returns the per-block,
/// per-site scales used (for inspection/tests).
pub fn smoothquant(
    params: &mut Params,
    calib_x: &Tensor,
    alpha: f32,
) -> Vec<BTreeMap<String, Vec<f32>>> {
    let cfg = params.cfg.clone();
    let mut x = calib_x.clone();
    let mut all_scales = Vec::new();
    for l in 0..cfg.n_layers {
        let bw = params.block(l);
        let opts = BlockFwdOpts { act_qmax: None, collect: true };
        let (y, taps) = block_fwd(&x, &bw, &cfg, &opts);
        let scales = smooth_block(params, l, &taps, alpha);
        all_scales.push(scales);
        x = y;
    }
    all_scales
}

fn smooth_block(
    params: &mut Params,
    l: usize,
    taps: &Taps,
    alpha: f32,
) -> BTreeMap<String, Vec<f32>> {
    let mut out = BTreeMap::new();

    // site 1: qkv input, carrier norm1
    {
        let am = act_absmax(&taps["qkv_in"]);
        let mut wm = vec![0.0f32; am.len()];
        for name in ["q_proj", "k_proj", "v_proj"] {
            let w = params.get(name).index0(l);
            for (m, v) in wm.iter_mut().zip(weight_col_absmax(&w)) {
                *m = m.max(v);
            }
        }
        let s = smooth_scales(&am, &wm, alpha);
        for name in ["q_proj", "k_proj", "v_proj"] {
            let mut w = params.get(name).index0(l);
            scale_cols(&mut w, &s);
            params.set_block_linear(l, name, &w);
        }
        let mut n1 = params.get("norm1").index0(l);
        for (nv, sv) in n1.data.iter_mut().zip(&s) {
            *nv /= sv;
        }
        params.get_mut("norm1").set_index0(l, &n1);
        out.insert("qkv".into(), s);
    }

    // site 2: o_proj input, carrier v_proj rows
    {
        let am = act_absmax(&taps["o_in"]);
        let w = params.get("o_proj").index0(l);
        let wm = weight_col_absmax(&w);
        let s = smooth_scales(&am, &wm, alpha);
        let mut wo = w;
        scale_cols(&mut wo, &s);
        params.set_block_linear(l, "o_proj", &wo);
        // o_proj input channel j is v head-dim lane j (heads concatenated):
        // v_proj output rows divide by s (with GQA, kv rows are repeated
        // `rep` times across heads; average the repeats' scales).
        let cfg = &params.cfg;
        let rep = cfg.n_heads / cfg.n_kv_heads;
        let hd = cfg.head_dim();
        let mut inv = vec![0.0f32; cfg.d_kv()];
        for kvh in 0..cfg.n_kv_heads {
            for t in 0..hd {
                let mut acc = 0.0f32;
                for r in 0..rep {
                    acc += 1.0 / s[(kvh * rep + r) * hd + t];
                }
                inv[kvh * hd + t] = acc / rep as f32;
            }
        }
        let mut wv = params.get("v_proj").index0(l);
        scale_rows(&mut wv, &inv);
        params.set_block_linear(l, "v_proj", &wv);
        out.insert("o".into(), s);
    }

    // site 3: gate/up input, carrier norm2
    {
        let am = act_absmax(&taps["mlp_in"]);
        let mut wm = vec![0.0f32; am.len()];
        for name in ["gate_proj", "up_proj"] {
            let w = params.get(name).index0(l);
            for (m, v) in wm.iter_mut().zip(weight_col_absmax(&w)) {
                *m = m.max(v);
            }
        }
        let s = smooth_scales(&am, &wm, alpha);
        for name in ["gate_proj", "up_proj"] {
            let mut w = params.get(name).index0(l);
            scale_cols(&mut w, &s);
            params.set_block_linear(l, name, &w);
        }
        let mut n2 = params.get("norm2").index0(l);
        for (nv, sv) in n2.data.iter_mut().zip(&s) {
            *nv /= sv;
        }
        params.get_mut("norm2").set_index0(l, &n2);
        out.insert("mlp".into(), s);
    }

    // site 4: down_proj input, carrier up_proj rows
    {
        let am = act_absmax(&taps["down_in"]);
        let w = params.get("down_proj").index0(l);
        let wm = weight_col_absmax(&w);
        let s = smooth_scales(&am, &wm, alpha);
        let mut wd = w;
        scale_cols(&mut wd, &s);
        params.set_block_linear(l, "down_proj", &wd);
        let inv: Vec<f32> = s.iter().map(|v| 1.0 / v).collect();
        let mut wu = params.get("up_proj").index0(l);
        scale_rows(&mut wu, &inv);
        params.set_block_linear(l, "up_proj", &wu);
        out.insert("down".into(), s);
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, Params};
    use crate::tensor::Pcg32;

    #[test]
    fn smoothquant_preserves_model_function() {
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(0);
        let mut p = Params::init(&cfg, &mut rng);
        let x = Tensor::randn(&[2, 16, cfg.d_model], 1.0, &mut rng);
        // full-model-ish check: run both blocks sequentially
        let run = |p: &Params| {
            let mut h = x.clone();
            for l in 0..cfg.n_layers {
                let (y, _) = block_fwd(&h, &p.block(l), &cfg, &BlockFwdOpts::default());
                h = y;
            }
            h
        };
        let y0 = run(&p);
        smoothquant(&mut p, &x, 0.5);
        let y1 = run(&p);
        let err = y0.mse(&y1);
        assert!(err < 1e-6, "smoothquant changed the function: mse {err}");
    }

    #[test]
    fn smoothing_reduces_act_outlier_ratio() {
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(1);
        let mut p = Params::init(&cfg, &mut rng);
        // inject an outlier channel into block-0 qkv input by scaling norm1
        let mut n1 = p.get("norm1").clone();
        n1.data[3] = 25.0;
        p.set("norm1", n1);
        let x = Tensor::randn(&[2, 16, cfg.d_model], 1.0, &mut rng);
        let taps_before = {
            let opts = BlockFwdOpts { act_qmax: None, collect: true };
            block_fwd(&x, &p.block(0), &cfg, &opts).1
        };
        let am0 = act_absmax(&taps_before["qkv_in"]);
        let ratio0 = am0.iter().cloned().fold(0.0f32, f32::max)
            / (am0.iter().sum::<f32>() / am0.len() as f32);
        smoothquant(&mut p, &x, 0.5);
        let taps_after = {
            let opts = BlockFwdOpts { act_qmax: None, collect: true };
            block_fwd(&x, &p.block(0), &cfg, &opts).1
        };
        let am1 = act_absmax(&taps_after["qkv_in"]);
        let ratio1 = am1.iter().cloned().fold(0.0f32, f32::max)
            / (am1.iter().sum::<f32>() / am1.len() as f32);
        assert!(ratio1 < ratio0, "outlier ratio {ratio0} -> {ratio1}");
    }
}
