//! QuaRot-style randomized orthogonal rotation of the residual stream.
//!
//! R = diag(signs) · H/sqrt(d) applied to the whole residual stream:
//! reader linears get W <- W R (rows through the signed Hadamard), writer
//! linears get W <- R^T W (columns), the embedding rows rotate, and the
//! final-norm weight folds into head_t = H diag(norm_f) H (the random
//! signs cancel). Activation outliers spread across channels, which is
//! what makes W4A4/W3A3 viable (paper Table 3).
//!
//! The paper's *online* per-FFN Hadamard (down_proj input) is not
//! reproduced — documented in DESIGN.md §2 substitutions.

use crate::model::transform::{extract_head_t, fold_norms};
use crate::model::Params;
use crate::tensor::linalg::{hadamard_inplace, signed_hadamard_inplace};
use crate::tensor::{Pcg32, Tensor};

pub struct Rotation {
    pub signs: Vec<f32>,
}

impl Rotation {
    pub fn random(d: usize, seed: u64) -> Rotation {
        let mut rng = Pcg32::seeded(seed);
        Rotation {
            signs: (0..d).map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 }).collect(),
        }
    }

    /// Right-multiply rows by R: each row r <- signed_hadamard(r).
    pub fn rotate_rows(&self, w: &mut Tensor) {
        signed_hadamard_inplace(&mut w.data, &self.signs);
    }

    /// Left-multiply by R^T = H diag(signs): each column c <- H (s .* c).
    pub fn rotate_cols(&self, w: &mut Tensor) {
        let mut wt = w.transpose2d();
        self.rotate_rows(&mut wt);
        *w = wt.transpose2d();
    }
}

/// Apply the rotation to a model in place and return the `head_t` matrix
/// the model_fwd_nll artifact needs. Folds all norms first.
pub fn rotate_model(params: &mut Params, seed: u64) -> Tensor {
    let d = params.cfg.d_model;
    assert!(d.is_power_of_two(), "rotation needs power-of-two d_model");
    fold_norms(params);
    let head_diag = extract_head_t(params); // diag(norm_f)
    let rot = Rotation::random(d, seed);

    // Embedding rows live in the residual basis.
    let mut emb = params.get("emb").clone();
    rot.rotate_rows(&mut emb);
    params.set("emb", emb);

    for l in 0..params.cfg.n_layers {
        for name in ["q_proj", "k_proj", "v_proj", "gate_proj", "up_proj"] {
            let mut w = params.get(name).index0(l);
            rot.rotate_rows(&mut w); // readers: W <- W R
            params.set_block_linear(l, name, &w);
        }
        for name in ["o_proj", "down_proj"] {
            let mut w = params.get(name).index0(l);
            rot.rotate_cols(&mut w); // writers: W <- R^T W
            params.set_block_linear(l, name, &w);
        }
    }

    // head_t = R^T diag(nf) R = H diag(nf) H (signs cancel).
    let mut head = head_diag;
    // rows: head <- head H  (apply plain hadamard to each row)
    hadamard_inplace(&mut head.data, d);
    // cols: head <- H head
    let mut ht = head.transpose2d();
    hadamard_inplace(&mut ht.data, d);
    ht.transpose2d()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::hostfwd::{block_fwd, BlockFwdOpts};
    use crate::model::ModelConfig;

    #[test]
    fn rotation_is_orthogonal() {
        let d = 64;
        let rot = Rotation::random(d, 0);
        let mut m = Tensor::zeros(&[d, d]);
        for i in 0..d {
            m.data[i * d + i] = 1.0;
        }
        // R^T R == I
        let mut r = m.clone();
        rot.rotate_rows(&mut r); // r = I R = R
        let mut rtr = r.clone();
        rot.rotate_cols(&mut rtr); // R^T R
        for i in 0..d {
            for j in 0..d {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (rtr.data[i * d + j] - want).abs() < 1e-4,
                    "({i},{j}) = {}",
                    rtr.data[i * d + j]
                );
            }
        }
    }

    /// Rotated block preserves residual-stream semantics: for input x,
    /// block_rot(x R) == block_orig(x) R.
    #[test]
    fn rotated_block_is_equivalent() {
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(1);
        let mut p = Params::init(&cfg, &mut rng);
        let shape = vec![cfg.n_layers, cfg.d_model];
        p.set("norm1", Tensor::from_fn(&shape, |i| 0.7 + (i % 5) as f32 * 0.1));
        p.set("norm2", Tensor::from_fn(&shape, |i| 0.9 + (i % 3) as f32 * 0.1));
        let x = Tensor::randn(&[1, 8, cfg.d_model], 1.0, &mut rng);
        let (y_orig, _) = block_fwd(&x, &p.block(0), &cfg, &BlockFwdOpts::default());

        let mut p_rot = p.clone();
        let _head = rotate_model(&mut p_rot, 99);
        let rot = Rotation::random(cfg.d_model, 99);
        let mut x_rot = x.clone();
        rot.rotate_rows(&mut x_rot);
        let (y_rot, _) = block_fwd(&x_rot, &p_rot.block(0), &cfg, &BlockFwdOpts::default());
        let mut y_want = y_orig.clone();
        rot.rotate_rows(&mut y_want);
        let err = y_rot.mse(&y_want);
        assert!(err < 1e-7, "rotation equivalence broke: mse {err}");
    }

    /// Rotation spreads outliers: max|activation| shrinks.
    #[test]
    fn rotation_suppresses_outliers() {
        let d = 128;
        let mut x = vec![0.1f32; d];
        x[7] = 30.0; // a massive outlier channel
        let rot = Rotation::random(d, 2);
        let mut t = Tensor::new(vec![1, d], x.clone());
        rot.rotate_rows(&mut t);
        let before = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let after = t.abs_max();
        assert!(after < before * 0.5, "outlier {before} -> {after}");
    }
}
