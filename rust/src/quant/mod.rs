//! Host-side quantizer — a bit-for-bit mirror of
//! python/compile/quantize.py (same clamp order, ties-to-even rounding,
//! SAT_NU saturation). The artifacts do the heavy fake-quant math during
//! calibration; this module owns initialization, merging, packing and the
//! serving-time dequant path.

pub mod pack;
pub mod rotate;
pub mod smooth;

use crate::tensor::Tensor;

/// Saturation logit for hardened rounding variables (== quantize.SAT_NU).
pub const SAT_NU: f32 = 100.0;
/// qmax sentinel meaning "FP activations" (matches act_fakequant).
pub const A16_SENTINEL: f32 = 65535.0;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupScheme {
    PerChannel,
    Group(usize),
}

impl GroupScheme {
    pub fn group_size(&self, in_features: usize) -> usize {
        match self {
            GroupScheme::PerChannel => in_features,
            GroupScheme::Group(g) => {
                assert_eq!(in_features % g, 0, "group {g} !| in {in_features}");
                *g
            }
        }
    }

    /// Artifact scheme tag ("pc", "g64", ...).
    pub fn tag(&self) -> String {
        match self {
            GroupScheme::PerChannel => "pc".into(),
            GroupScheme::Group(g) => format!("g{g}"),
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<GroupScheme> {
        if s == "pc" {
            Ok(GroupScheme::PerChannel)
        } else if let Some(g) = s.strip_prefix('g') {
            Ok(GroupScheme::Group(g.parse()?))
        } else {
            anyhow::bail!("bad group scheme {s:?} (want pc|gN)")
        }
    }
}

/// A full quantization configuration in the paper's W{n}A{m}g{k} notation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantConfig {
    pub w_bits: u32,
    pub scheme: GroupScheme,
    /// None = FP16 activations (A16).
    pub act_bits: Option<u32>,
}

impl QuantConfig {
    pub fn new(w_bits: u32, scheme: GroupScheme, act_bits: Option<u32>) -> Self {
        QuantConfig { w_bits, scheme, act_bits }
    }

    pub fn weight_only(w_bits: u32, scheme: GroupScheme) -> Self {
        Self::new(w_bits, scheme, None)
    }

    pub fn qmax_w(&self) -> f32 {
        (2u32.pow(self.w_bits) - 1) as f32
    }

    pub fn qmax_act(&self) -> f32 {
        match self.act_bits {
            None => A16_SENTINEL,
            Some(b) => (2u32.pow(b) - 1) as f32,
        }
    }

    /// Paper notation, e.g. "W2A16g128".
    pub fn label(&self) -> String {
        let a = self.act_bits.map_or(16, |b| b);
        let g = match self.scheme {
            GroupScheme::PerChannel => String::new(),
            GroupScheme::Group(g) => format!("g{g}"),
        };
        format!("W{}A{a}{g}", self.w_bits)
    }

    /// Parse paper notation ("W2A16g128", case-insensitive) back into a
    /// config — the inverse of [`QuantConfig::label`].
    pub fn parse(s: &str) -> anyhow::Result<QuantConfig> {
        use anyhow::Context;
        let up = s.to_uppercase();
        let rest = up
            .strip_prefix('W')
            .with_context(|| format!("quant config {s:?} must start with W"))?;
        let apos = rest
            .find('A')
            .with_context(|| format!("quant config {s:?} needs A<bits>"))?;
        let w_bits: u32 = rest[..apos]
            .parse()
            .with_context(|| format!("bad weight bits in {s:?}"))?;
        let rest = &rest[apos + 1..];
        let (a_str, g_str) = match rest.find('G') {
            Some(g) => (&rest[..g], Some(&rest[g + 1..])),
            None => (rest, None),
        };
        let a_bits: u32 = a_str
            .parse()
            .with_context(|| format!("bad act bits in {s:?}"))?;
        let scheme = match g_str {
            Some(g) => GroupScheme::Group(
                g.parse().with_context(|| format!("bad group size in {s:?}"))?,
            ),
            None => GroupScheme::PerChannel,
        };
        Ok(QuantConfig::new(w_bits, scheme, if a_bits >= 16 { None } else { Some(a_bits) }))
    }
}

/// jnp.round semantics: ties to even.
#[inline]
pub fn round_te(x: f32) -> f32 {
    x.round_ties_even()
}

/// Per-group scale/zero-point, shapes [out, n_groups].
#[derive(Debug, Clone, PartialEq)]
pub struct QParams {
    pub s: Tensor,
    pub z: Tensor,
    pub group: usize,
}

impl QParams {
    pub fn n_groups(&self) -> usize {
        self.s.shape[1]
    }
}

/// Asymmetric min/max scale with clip factors (paper Eq. 1; mirror of
/// quantize.minmax_scale). gamma/beta may be scalar (uniform clipping) or
/// per-group tensors [out, n_groups] (AWQ/LWC output).
pub fn minmax_scale(
    w: &Tensor,
    group: usize,
    gamma: &ClipFactors,
    beta: &ClipFactors,
    qmax: f32,
) -> QParams {
    let (o, i) = w.dims2();
    assert_eq!(i % group, 0);
    let ng = i / group;
    let mut s = vec![0.0f32; o * ng];
    let mut z = vec![0.0f32; o * ng];
    for r in 0..o {
        for g in 0..ng {
            let seg = &w.data[r * i + g * group..r * i + (g + 1) * group];
            let mx = seg.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let mn = seg.iter().fold(f32::INFINITY, |m, &v| m.min(v));
            let ga = gamma.at(r, g);
            let be = beta.at(r, g);
            let sv = ((ga * mx - be * mn) / qmax).max(1e-9);
            s[r * ng + g] = sv;
            z[r * ng + g] = round_te(-be * mn / sv);
        }
    }
    QParams {
        s: Tensor::new(vec![o, ng], s),
        z: Tensor::new(vec![o, ng], z),
        group,
    }
}

/// Scalar-or-tensor clip factor.
pub enum ClipFactors {
    Uniform(f32),
    PerGroup(Tensor),
}

impl ClipFactors {
    #[inline]
    fn at(&self, r: usize, g: usize) -> f32 {
        match self {
            ClipFactors::Uniform(v) => *v,
            ClipFactors::PerGroup(t) => t.data[r * t.shape[1] + g],
        }
    }
}

/// Integer codes from round-to-nearest: clamp(round(w/s)+z, 0, qmax).
pub fn rtn_codes(w: &Tensor, qp: &QParams, qmax: f32) -> Vec<u16> {
    let (o, i) = w.dims2();
    let ng = qp.n_groups();
    let g = qp.group;
    let mut codes = vec![0u16; o * i];
    for r in 0..o {
        for c in 0..i {
            let gi = c / g;
            let s = qp.s.data[r * ng + gi];
            let z = qp.z.data[r * ng + gi];
            let q = (round_te(w.data[r * i + c] / s) + z).clamp(0.0, qmax);
            codes[r * i + c] = q as u16;
        }
    }
    codes
}

/// Dequantize integer codes: s * (q - z), with optional effective scale
/// override (DST-merged checkpoints store s_eff = 2*sigmoid(v)*s).
pub fn dequant_codes(codes: &[u16], o: usize, i: usize, qp: &QParams) -> Tensor {
    let ng = qp.n_groups();
    let g = qp.group;
    let mut w = vec![0.0f32; o * i];
    for r in 0..o {
        for c in 0..i {
            let gi = c / g;
            w[r * i + c] =
                qp.s.data[r * ng + gi] * (codes[r * i + c] as f32 - qp.z.data[r * ng + gi]);
        }
    }
    Tensor::new(vec![o, i], w)
}

/// RTN fake-quant in one shot.
pub fn rtn_qdq(w: &Tensor, qp: &QParams, qmax: f32) -> Tensor {
    let (o, i) = w.dims2();
    dequant_codes(&rtn_codes(w, qp, qmax), o, i, qp)
}

/// floor(W/s) on the group grid (mirror of quantize.w_floor_init).
pub fn w_floor(w: &Tensor, qp: &QParams) -> Tensor {
    let (o, i) = w.dims2();
    let ng = qp.n_groups();
    let g = qp.group;
    let mut out = vec![0.0f32; o * i];
    for r in 0..o {
        for c in 0..i {
            let s = qp.s.data[r * ng + c / g];
            out[r * i + c] = (w.data[r * i + c] / s).floor();
        }
    }
    Tensor::new(vec![o, i], out)
}

/// Rounding-logit init: sigma^-1(clip(frac(W/s), 1e-4, 1-1e-4)).
pub fn nu_init(w: &Tensor, qp: &QParams) -> Tensor {
    let (o, i) = w.dims2();
    let ng = qp.n_groups();
    let g = qp.group;
    let mut out = vec![0.0f32; o * i];
    for r in 0..o {
        for c in 0..i {
            let s = qp.s.data[r * ng + c / g];
            let ratio = w.data[r * i + c] / s;
            let frac = (ratio - ratio.floor()).clamp(1e-4, 1.0 - 1e-4);
            out[r * i + c] = (frac / (1.0 - frac)).ln();
        }
    }
    Tensor::new(vec![o, i], out)
}

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Hard quant codes from PAR state: clamp(wfloor + 1[nu>0] + z, 0, qmax).
pub fn hard_codes(wf: &Tensor, nu: &Tensor, qp: &QParams, qmax: f32) -> Vec<u16> {
    let (o, i) = wf.dims2();
    let ng = qp.n_groups();
    let g = qp.group;
    let mut codes = vec![0u16; o * i];
    for r in 0..o {
        for c in 0..i {
            let z = qp.z.data[r * ng + c / g];
            let alpha = if nu.data[r * i + c] > 0.0 { 1.0 } else { 0.0 };
            codes[r * i + c] = (wf.data[r * i + c] + alpha + z).clamp(0.0, qmax) as u16;
        }
    }
    codes
}

/// Effective dequant scale after DST: s_eff = 2*sigmoid(v)*s.
pub fn dst_effective_scale(qp: &QParams, v: &Tensor) -> QParams {
    assert_eq!(qp.s.shape, v.shape);
    let s = Tensor::new(
        qp.s.shape.clone(),
        qp.s
            .data
            .iter()
            .zip(&v.data)
            .map(|(&s, &vv)| 2.0 * sigmoid(vv) * s)
            .collect(),
    );
    QParams { s, z: qp.z.clone(), group: qp.group }
}

/// Per-token (row) asymmetric activation fake-quant, in place.
/// Mirror of quantize.act_fakequant (qmax >= 60000 -> passthrough).
pub fn act_fakequant_rows(data: &mut [f32], width: usize, qmax: f32) {
    if qmax >= 60000.0 {
        return;
    }
    for row in data.chunks_mut(width) {
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mn = row.iter().fold(f32::INFINITY, |m, &v| m.min(v));
        let s = ((mx - mn) / qmax).max(1e-8);
        let z = round_te(-mn / s);
        for v in row.iter_mut() {
            let q = (round_te(*v / s) + z).clamp(0.0, qmax);
            *v = s * (q - z);
        }
    }
}

/// Number of PAR rounding variables that flipped vs RTN (Table 7): a flip
/// means hard(nu) != round-to-nearest of the original fractional part.
pub fn count_flips(w: &Tensor, nu: &Tensor, qp: &QParams) -> usize {
    let (o, i) = w.dims2();
    let ng = qp.n_groups();
    let g = qp.group;
    let mut flips = 0usize;
    for r in 0..o {
        for c in 0..i {
            let s = qp.s.data[r * ng + c / g];
            let ratio = w.data[r * i + c] / s;
            let frac = ratio - ratio.floor();
            let rtn_up = frac >= 0.5;
            let par_up = nu.data[r * i + c] > 0.0;
            if rtn_up != par_up {
                flips += 1;
            }
        }
    }
    flips
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg32;

    fn mk(o: usize, i: usize, seed: u64) -> Tensor {
        let mut rng = Pcg32::seeded(seed);
        Tensor::randn(&[o, i], 1.0, &mut rng)
    }

    #[test]
    fn rtn_error_bounded_by_step() {
        let w = mk(8, 32, 0);
        let qp = minmax_scale(&w, 16, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), 15.0);
        let what = rtn_qdq(&w, &qp, 15.0);
        for r in 0..8 {
            for c in 0..32 {
                let s = qp.s.data[r * 2 + c / 16];
                let err = (w.data[r * 32 + c] - what.data[r * 32 + c]).abs();
                assert!(err <= 0.75 * s + 1e-6, "err {err} step {s}");
            }
        }
    }

    #[test]
    fn nu_init_reconstructs_weight() {
        // soft qdq with nu_init and v=0 must reproduce w (inside clamp)
        let w = mk(4, 32, 1);
        let qmax = 15.0;
        let qp = minmax_scale(&w, 8, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), qmax);
        let wf = w_floor(&w, &qp);
        let nu = nu_init(&w, &qp);
        let ng = qp.n_groups();
        let mut max_err = 0.0f32;
        let mut interior = 0usize;
        for r in 0..4 {
            for c in 0..32 {
                let s = qp.s.data[r * ng + c / 8];
                let z = qp.z.data[r * ng + c / 8];
                let alpha = sigmoid(nu.data[r * 32 + c]);
                let raw = wf.data[r * 32 + c] + alpha + z;
                if raw < 0.0 || raw > qmax {
                    continue; // clamped boundary point: error up to one step
                }
                interior += 1;
                let what = s * (raw - z);
                let err = (what - w.data[r * 32 + c]).abs() / s;
                max_err = max_err.max(err);
            }
        }
        assert!(interior > 64, "too few interior points ({interior})");
        assert!(max_err < 0.01, "interior reconstruction err {max_err}");
    }

    #[test]
    fn hard_codes_within_range() {
        let w = mk(4, 16, 2);
        let qmax = 3.0;
        let qp = minmax_scale(&w, 16, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), qmax);
        let wf = w_floor(&w, &qp);
        let nu = nu_init(&w, &qp);
        let codes = hard_codes(&wf, &nu, &qp, qmax);
        assert!(codes.iter().all(|&c| c <= 3));
    }

    #[test]
    fn dst_scale_identity_at_zero() {
        let w = mk(4, 16, 3);
        let qp = minmax_scale(&w, 16, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), 15.0);
        let v = Tensor::zeros(&qp.s.shape);
        let qp2 = dst_effective_scale(&qp, &v);
        for (a, b) in qp.s.data.iter().zip(&qp2.s.data) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn act_fakequant_row_levels() {
        let mut rng = Pcg32::seeded(4);
        let mut x: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let orig = x.clone();
        act_fakequant_rows(&mut x, 16, 7.0);
        assert_ne!(x, orig);
        for row in x.chunks(16) {
            let mut uniq: Vec<f32> = row.to_vec();
            uniq.sort_by(|a, b| a.partial_cmp(b).unwrap());
            uniq.dedup_by(|a, b| (*a - *b).abs() < 1e-7);
            assert!(uniq.len() <= 8, "more than 2^3 levels per token");
        }
        // sentinel passthrough
        let mut y = orig.clone();
        act_fakequant_rows(&mut y, 16, A16_SENTINEL);
        assert_eq!(y, orig);
    }

    #[test]
    fn flips_zero_at_rtn_init() {
        let w = mk(4, 32, 5);
        let qp = minmax_scale(&w, 32, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), 3.0);
        let nu = nu_init(&w, &qp);
        // nu_init gives sigmoid(nu) = frac, so "nu > 0" == "frac > 0.5" == RTN
        assert_eq!(count_flips(&w, &nu, &qp), 0);
    }

    #[test]
    fn quant_config_labels() {
        assert_eq!(
            QuantConfig::weight_only(2, GroupScheme::Group(128)).label(),
            "W2A16g128"
        );
        assert_eq!(
            QuantConfig::new(4, GroupScheme::PerChannel, Some(4)).label(),
            "W4A4"
        );
        assert_eq!(GroupScheme::parse("g64").unwrap(), GroupScheme::Group(64));
        assert_eq!(GroupScheme::parse("pc").unwrap(), GroupScheme::PerChannel);
        assert!(GroupScheme::parse("x2").is_err());
    }

    #[test]
    fn quant_config_parse_roundtrip() {
        for s in ["W2A16g128", "W4A4", "W3A16g64", "W8A8g32"] {
            let c = QuantConfig::parse(s).unwrap();
            assert_eq!(c.label(), s, "roundtrip {s}");
        }
        assert_eq!(
            QuantConfig::parse("w2a16G128").unwrap(),
            QuantConfig::weight_only(2, GroupScheme::Group(128)),
            "case-insensitive"
        );
        assert!(QuantConfig::parse("2A16").is_err());
        assert!(QuantConfig::parse("W2").is_err());
        assert!(QuantConfig::parse("WxAy").is_err());
    }
}
