//! Evaluation harness: perplexity over held-out corpus sequences and
//! zero-shot likelihood-ranking accuracy, both driven through the
//! `model_fwd_nll.<size>` artifact (Python never runs here).

use anyhow::Result;
use std::rc::Rc;

use crate::data::{Corpus, Task};
use crate::model::transform::identity_head_t;
use crate::model::Params;
use crate::runtime::{Arg, Artifact, Engine};
use crate::tensor::Tensor;

pub struct Evaluator<'e> {
    eng: &'e Engine,
    art: Rc<Artifact>,
    pub batch: usize,
    pub seq: usize,
}

impl<'e> Evaluator<'e> {
    pub fn new(eng: &'e Engine, size: &str) -> Result<Self> {
        let art = eng.artifact(&format!("model_fwd_nll.{size}"))?;
        let batch = art.spec.meta.eval_batch;
        let seq = art.spec.meta.model.max_seq;
        Ok(Evaluator { eng, art, batch, seq })
    }

    /// NLL matrix [batch, seq-1] for one token batch. `head_t` carries
    /// diag(norm_f) folding and/or the QuaRot rotation; pass None for an
    /// untransformed model (identity x norm_f handled inside the graph is
    /// NOT done — norm_f must be 1s when head_t is supplied).
    pub fn nll(
        &self,
        params: &Params,
        head_t: Option<&Tensor>,
        qmax_act: f32,
        tokens: &[i32],
    ) -> Result<Tensor> {
        let ident;
        let head = match head_t {
            Some(h) => h,
            None => {
                ident = identity_head_t(params.cfg.d_model);
                &ident
            }
        };
        let p_ord = params.ordered();
        let tok_shape = [self.batch, self.seq];
        let mut args: Vec<Arg> = vec![Arg::I32(tokens, &tok_shape)];
        args.extend(p_ord.iter().map(|t| Arg::F32(t)));
        args.push(Arg::F32(head));
        args.push(Arg::Scalar(qmax_act));
        let mut outs = self.eng.run(&self.art, &args)?;
        Ok(outs.remove(0))
    }

    /// Token-level perplexity over `n_seq` sequences (padded up to a
    /// multiple of the eval batch).
    pub fn perplexity(
        &self,
        params: &Params,
        head_t: Option<&Tensor>,
        qmax_act: f32,
        corpus: &Corpus,
        n_seq: usize,
        seed: u64,
    ) -> Result<f64> {
        let mut total = 0.0f64;
        let mut count = 0usize;
        let mut done = 0usize;
        while done < n_seq {
            let b = self.batch.min(n_seq - done);
            let mut tokens = corpus.sequences(b, self.seq, seed.wrapping_add(done as u64));
            // pad the batch to the artifact shape with repeats
            while tokens.len() < self.batch * self.seq {
                let row = tokens[..self.seq].to_vec();
                tokens.extend(row);
            }
            let nll = self.nll(params, head_t, qmax_act, &tokens)?;
            let w = self.seq - 1;
            for r in 0..b {
                for c in 0..w {
                    total += nll.data[r * w + c] as f64;
                    count += 1;
                }
            }
            done += b;
        }
        Ok((total / count as f64).exp())
    }

    /// Zero-shot accuracy on a likelihood-ranking task: pick the candidate
    /// continuation with the lower summed NLL after the shared prefix.
    pub fn zeroshot(
        &self,
        params: &Params,
        head_t: Option<&Tensor>,
        qmax_act: f32,
        task: &Task,
    ) -> Result<f64> {
        let pad = 0i32;
        let mut correct = 0usize;
        let mut idx = 0usize;
        while idx < task.items.len() {
            // pack up to batch/2 items (2 sequences each) per call
            let take = (self.batch / 2).min(task.items.len() - idx);
            let mut tokens = vec![pad; self.batch * self.seq];
            let mut spans = Vec::new(); // (row, start, len)
            for (slot, item) in task.items[idx..idx + take].iter().enumerate() {
                for (ci, cand) in item.cand.iter().enumerate() {
                    let row = slot * 2 + ci;
                    let mut seq = item.prefix.clone();
                    let start = seq.len();
                    seq.extend(cand);
                    assert!(seq.len() <= self.seq, "item longer than max_seq");
                    tokens[row * self.seq..row * self.seq + seq.len()]
                        .copy_from_slice(&seq);
                    spans.push((row, start, cand.len()));
                }
            }
            let nll = self.nll(params, head_t, qmax_act, &tokens)?;
            let w = self.seq - 1;
            for (slot, item) in task.items[idx..idx + take].iter().enumerate() {
                let mut scores = [0.0f64; 2];
                for ci in 0..2 {
                    let (row, start, len) = spans[slot * 2 + ci];
                    for p in cand_nll_range(start, len) {
                        scores[ci] += nll.data[row * w + p] as f64;
                    }
                }
                let pick = if scores[0] <= scores[1] { 0 } else { 1 };
                if pick == item.label {
                    correct += 1;
                }
            }
            idx += take;
        }
        Ok(correct as f64 / task.items.len() as f64)
    }

    /// Average accuracy over the five synthetic tasks (the tables' "Avg").
    pub fn zeroshot_suite(
        &self,
        params: &Params,
        head_t: Option<&Tensor>,
        qmax_act: f32,
        corpus: &Corpus,
        n_items: usize,
        prefix_len: usize,
    ) -> Result<Vec<(String, f64)>> {
        let mut out = Vec::new();
        let mut sum = 0.0;
        for kind in crate::data::tasks::ALL_TASKS {
            let task = Task::generate(kind, corpus, n_items, prefix_len);
            let acc = self.zeroshot(params, head_t, qmax_act, &task)?;
            sum += acc;
            out.push((kind.name().to_string(), acc));
        }
        out.push(("Avg".to_string(), sum / 5.0));
        Ok(out)
    }
}

/// NLL positions scoring a candidate at `start..start+len` in a packed
/// row. `nll[r, p]` is the NLL of predicting token p+1, so the candidate
/// is scored at p = start-1 .. start+len-2 — EXCEPT when the task prefix
/// is empty (start == 0): the candidate's first token has no conditioning
/// position, so scoring starts at p = 0 (its second token). The old
/// unguarded `start - 1` underflowed usize and panicked on such tasks.
pub fn cand_nll_range(start: usize, len: usize) -> std::ops::Range<usize> {
    if len == 0 {
        return 0..0;
    }
    start.saturating_sub(1)..start + len - 1
}

#[cfg(test)]
mod tests {
    use super::cand_nll_range;

    #[test]
    fn cand_range_with_prefix() {
        // prefix of 3, candidate of 2 at positions 3..5: scored at p=2,3
        assert_eq!(cand_nll_range(3, 2), 2..4);
        // single-token candidate after a prefix: one position
        assert_eq!(cand_nll_range(5, 1), 4..5);
    }

    #[test]
    fn cand_range_empty_prefix_does_not_underflow() {
        // the regression: start == 0 used to compute (0usize - 1)
        let r = cand_nll_range(0, 4);
        assert_eq!(r, 0..3);
        // a 1-token candidate with no prefix has nothing to score
        assert_eq!(cand_nll_range(0, 1), 0..0);
    }

    #[test]
    fn cand_range_empty_candidate_is_empty() {
        assert_eq!(cand_nll_range(7, 0), 0..0);
        assert_eq!(cand_nll_range(0, 0), 0..0);
    }
}
