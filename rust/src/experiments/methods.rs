//! PTQ method dispatch: quantize a pretrained model with any of the
//! paper's methods/compositions and return the quantized params plus
//! whatever the evaluator needs (head_t for rotated models, the
//! calibration report for serving/stats).

use anyhow::Result;

use crate::baselines::awq::{awq_transform, quantize_with_clips};
use crate::coordinator::driver::{CalibReport, GptqOptimizer, ReconstructionDriver};
use crate::coordinator::lwc::{calibrate_lwc_robust, LwcConfig};
use crate::coordinator::par::{calibrate_tesseraq_robust, TesseraqConfig};
use crate::coordinator::Schedule;
use crate::robust::RobustConfig;
use crate::data::Corpus;
use crate::model::Params;
use crate::quant::rotate::rotate_model;
use crate::quant::smooth::smoothquant;
use crate::quant::{minmax_scale, rtn_qdq, ClipFactors, QuantConfig};
use crate::runtime::Engine;
use crate::tensor::Tensor;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Fp16,
    Rtn,
    Gptq,
    Awq,
    /// OmniQuant-style learnable weight clipping.
    OmniQuant,
    /// TesseraQ initialized from AWQ (the paper's default, "TesseraQ*").
    TesseraQ,
    /// TesseraQ initialized from OmniQuant clips ("TesseraQ†", W2A16).
    TesseraQLwc,
    /// GPTQ applied on an AWQ checkpoint (Fig. 2's failed composition).
    GptqOnAwq,
    SmoothQuant,
    /// QuaRot rotation + RTN.
    QuaRot,
    /// QuaRot + GPTQ ("GPTQ†").
    QuaRotGptq,
    /// QuaRot + TesseraQ ("TesseraQ†", W-A tables).
    QuaRotTesseraQ,
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::Fp16 => "FP16",
            Method::Rtn => "RTN",
            Method::Gptq => "GPTQ",
            Method::Awq => "AWQ",
            Method::OmniQuant => "OmniQuant",
            Method::TesseraQ => "TesseraQ*",
            Method::TesseraQLwc => "TesseraQ+",
            Method::GptqOnAwq => "GPTQ-on-AWQ",
            Method::SmoothQuant => "SmoothQuant",
            Method::QuaRot => "QuaRot",
            Method::QuaRotGptq => "GPTQ(rot)",
            Method::QuaRotTesseraQ => "TesseraQ(rot)",
        }
    }
}

pub struct Quantized {
    pub params: Params,
    /// head matrix for model_fwd_nll (None = identity/norm_f in place)
    pub head_t: Option<Tensor>,
    pub report: Option<CalibReport>,
}

pub struct MethodOpts {
    pub n_seq: usize,
    pub seed: u64,
    pub tesseraq: TesseraqConfig,
    pub lwc: LwcConfig,
    pub schedule: Schedule,
    /// Resilience knobs (checkpointing, sentinels, retry, fault plan) for
    /// the TesseraQ calibration arms.
    pub robust: RobustConfig,
}

impl MethodOpts {
    pub fn new(qcfg: QuantConfig, n_seq: usize, fast: bool) -> MethodOpts {
        let mut t = if fast {
            TesseraqConfig::fast(qcfg)
        } else {
            TesseraqConfig::standard(qcfg)
        };
        t.propagate_act_quant = qcfg.act_bits.is_some();
        let mut l = if fast { LwcConfig::fast(qcfg) } else { LwcConfig::standard(qcfg) };
        l.propagate_act_quant = qcfg.act_bits.is_some();
        MethodOpts {
            n_seq,
            seed: 0xCA11B,
            tesseraq: t,
            lwc: l,
            schedule: Schedule::Handcrafted,
            robust: RobustConfig::default(),
        }
    }
}

/// RTN over every linear (host).
pub fn rtn_model(params: &mut Params, qcfg: &QuantConfig) {
    let qmax = qcfg.qmax_w();
    for l in 0..params.cfg.n_layers {
        let bw = params.block(l);
        for (name, w) in &bw.linears {
            let g = qcfg.scheme.group_size(w.shape[1]);
            let qp = minmax_scale(w, g, &ClipFactors::Uniform(1.0), &ClipFactors::Uniform(1.0), qmax);
            params.set_block_linear(l, name, &rtn_qdq(w, &qp, qmax));
        }
    }
}

/// GPTQ block-by-block with quantized-prefix propagation, through the
/// unified [`ReconstructionDriver`] (checkpoint/resume, retry, fault
/// injection). The GPTQ math itself stays host-side; `eng` only speeds
/// up the block forwards.
pub fn gptq_model(
    eng: Option<&Engine>,
    params: &mut Params,
    tokens: &[i32],
    n_seq: usize,
    qcfg: &QuantConfig,
    robust: &RobustConfig,
) -> Result<CalibReport> {
    let driver = ReconstructionDriver::new(eng, robust);
    let mut opt = GptqOptimizer::new(*qcfg);
    driver.run(params, &mut opt, tokens, n_seq)
}

/// Quantize `base` (FP checkpoint) with `method`.
pub fn quantize(
    eng: &Engine,
    base: &Params,
    method: Method,
    qcfg: &QuantConfig,
    corpus: &Corpus,
    opts: &MethodOpts,
) -> Result<Quantized> {
    let cfg = base.cfg.clone();
    let tokens = corpus.sequences(opts.n_seq, cfg.max_seq, opts.seed);
    let calib_x = || base.embed(&tokens, opts.n_seq, cfg.max_seq);
    let mut params = base.clone();
    let mut head_t = None;
    let mut report = None;

    match method {
        Method::Fp16 => {}
        Method::Rtn => rtn_model(&mut params, qcfg),
        Method::Gptq => {
            report = Some(gptq_model(
                Some(eng), &mut params, &tokens, opts.n_seq, qcfg, &opts.robust,
            )?);
        }
        Method::Awq => {
            let res = awq_transform(&mut params, &calib_x(), qcfg, 16, 6);
            quantize_with_clips(&mut params, &res.clips, qcfg);
        }
        Method::OmniQuant => {
            let lrep = calibrate_lwc_robust(
                Some(eng), &mut params, &tokens, opts.n_seq, &opts.lwc, &opts.robust,
            )?;
            report = Some(lrep.calib);
        }
        Method::TesseraQ => {
            let res = awq_transform(&mut params, &calib_x(), qcfg, 16, 6);
            let mut tcfg = opts.tesseraq.clone();
            tcfg.schedule = opts.schedule;
            report = Some(calibrate_tesseraq_robust(
                Some(eng), &mut params, Some(&res.clips), &tokens, opts.n_seq, &tcfg,
                &opts.robust,
            )?);
        }
        Method::TesseraQLwc => {
            // learn clips on a clone (OmniQuant init), then PAR on the
            // original weights with those clips — the paper's W2A16 recipe
            let mut probe = params.clone();
            let lrep = calibrate_lwc_robust(
                Some(eng), &mut probe, &tokens, opts.n_seq, &opts.lwc, &opts.robust,
            )?;
            let mut tcfg = opts.tesseraq.clone();
            tcfg.schedule = opts.schedule;
            report = Some(calibrate_tesseraq_robust(
                Some(eng), &mut params, Some(&lrep.clips), &tokens, opts.n_seq, &tcfg,
                &opts.robust,
            )?);
        }
        Method::GptqOnAwq => {
            awq_transform(&mut params, &calib_x(), qcfg, 16, 6);
            report = Some(gptq_model(
                Some(eng), &mut params, &tokens, opts.n_seq, qcfg, &opts.robust,
            )?);
        }
        Method::SmoothQuant => {
            smoothquant(&mut params, &calib_x(), 0.5);
            rtn_model(&mut params, qcfg);
        }
        Method::QuaRot => {
            head_t = Some(rotate_model(&mut params, R0_SEED));
            rtn_model(&mut params, qcfg);
        }
        Method::QuaRotGptq => {
            head_t = Some(rotate_model(&mut params, R0_SEED));
            // tokens embed must use the ROTATED embedding
            let rtokens = tokens.clone();
            report = Some(gptq_model(
                Some(eng), &mut params, &rtokens, opts.n_seq, qcfg, &opts.robust,
            )?);
        }
        Method::QuaRotTesseraQ => {
            head_t = Some(rotate_model(&mut params, R0_SEED));
            let mut tcfg = opts.tesseraq.clone();
            tcfg.schedule = opts.schedule;
            report = Some(calibrate_tesseraq_robust(
                Some(eng), &mut params, None, &tokens, opts.n_seq, &tcfg, &opts.robust,
            )?);
        }
    }
    Ok(Quantized { params, head_t, report })
}

/// qmax_act to use at evaluation time for a quant config.
pub fn eval_qmax_act(qcfg: &QuantConfig) -> f32 {
    qcfg.qmax_act()
}


#[allow(non_upper_case_globals)]
const R0_SEED: u64 = 0x1207;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::GroupScheme;

    #[test]
    fn labels_unique() {
        let all = [
            Method::Fp16, Method::Rtn, Method::Gptq, Method::Awq,
            Method::OmniQuant, Method::TesseraQ, Method::TesseraQLwc,
            Method::GptqOnAwq, Method::SmoothQuant, Method::QuaRot,
            Method::QuaRotGptq, Method::QuaRotTesseraQ,
        ];
        let mut labels: Vec<_> = all.iter().map(|m| m.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), all.len());
    }

    #[test]
    fn method_opts_propagate_act_quant() {
        let qcfg = QuantConfig::new(4, GroupScheme::PerChannel, Some(4));
        let o = MethodOpts::new(qcfg, 16, true);
        assert!(o.tesseraq.propagate_act_quant);
        assert!(o.lwc.propagate_act_quant);
        let q2 = QuantConfig::weight_only(2, GroupScheme::Group(64));
        assert!(!MethodOpts::new(q2, 16, true).tesseraq.propagate_act_quant);
    }
}
