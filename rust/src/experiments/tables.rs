//! Per-table/figure regenerators (DESIGN.md §5 experiment index).
//!
//! Absolute numbers differ from the paper (tiny synthetic substrate, CPU
//! PJRT), but the *shape* — method ordering, bit-width trends, crossover
//! points — is the reproduction target.

use anyhow::{bail, Context, Result};

use super::methods::{quantize, Method, MethodOpts, Quantized};
use super::Ctx;
use crate::coordinator::Schedule;
use crate::data::{Corpus, CorpusKind};
use crate::eval::Evaluator;
use crate::model::Params;
use crate::quant::{GroupScheme, QuantConfig};
use crate::report::{append_log, fmt_acc, fmt_bytes, fmt_ppl, Table};
use crate::serve::ServeModel;

pub fn run_table(ctx: &Ctx, id: u32) -> Result<()> {
    match id {
        1 | 9 => table1_and_9(ctx),
        2 => table2(ctx),
        3 | 12 => table3(ctx),
        4 => table4(ctx),
        5 => table5(ctx),
        6 => table6(ctx),
        7 => table7(ctx),
        8 => table8(ctx),
        10 => table10(ctx),
        11 => table11(ctx),
        _ => bail!("unknown table {id} (have 1-12)"),
    }
}

pub fn run_figure(ctx: &Ctx, id: u32) -> Result<()> {
    match id {
        2 => figure2(ctx),
        3 => figure3(ctx),
        4 => figure4(ctx),
        _ => bail!("unknown figure {id} (have 2-4)"),
    }
}

struct EvalOut {
    ppl_wiki: f64,
    ppl_c4: f64,
    accs: Vec<(String, f64)>,
}

fn evaluate(
    ctx: &Ctx,
    size: &str,
    q: &Quantized,
    qcfg: &QuantConfig,
    with_acc: bool,
) -> Result<EvalOut> {
    let ev = Evaluator::new(&ctx.eng, size)?;
    let qa = qcfg.qmax_act();
    let wiki = ctx.corpus(CorpusKind::WikiLike, size)?;
    let c4 = ctx.corpus(CorpusKind::C4Like, size)?;
    let ppl_wiki =
        ev.perplexity(&q.params, q.head_t.as_ref(), qa, &wiki, ctx.n_eval(), 0xEA1)?;
    let ppl_c4 = ev.perplexity(&q.params, q.head_t.as_ref(), qa, &c4, ctx.n_eval(), 0xEA2)?;
    let accs = if with_acc {
        ev.zeroshot_suite(&q.params, q.head_t.as_ref(), qa, &wiki, ctx.n_items(), 24)?
    } else {
        Vec::new()
    };
    Ok(EvalOut { ppl_wiki, ppl_c4, accs })
}

fn avg_acc(accs: &[(String, f64)]) -> f64 {
    accs.iter().find(|(n, _)| n == "Avg").map(|(_, a)| *a).unwrap_or(f64::NAN)
}

/// Persist a calibration report as a JSON artifact next to the markdown
/// tables (machine-readable per-block traces incl. fallback blocks).
fn emit_calib_json(tag: &str, report: Option<&crate::coordinator::par::CalibReport>) {
    if let Some(r) = report {
        if let Err(e) = crate::report::write_json(tag, &r.to_json()) {
            eprintln!("[report] could not write {tag}.json: {e:#}");
        }
    }
}

fn run_method(
    ctx: &Ctx,
    base: &Params,
    method: Method,
    qcfg: &QuantConfig,
    calib: &Corpus,
) -> Result<Quantized> {
    crate::obs::warn(
        "warn",
        &format!("[{}] {} ...", qcfg.label(), method.label()),
        &[("method", method.label().into()), ("config", qcfg.label().into())],
    );
    let mut opts = MethodOpts::new(*qcfg, ctx.n_calib(), ctx.fast);
    opts.robust = ctx.robust.clone();
    let q = quantize(&ctx.eng, base, method, qcfg, calib, &opts)?;
    emit_calib_json(
        &format!("calib_{}_{}", method.label(), qcfg.label()),
        q.report.as_ref(),
    );
    Ok(q)
}

// -- Table 1 (WikiText2 PPL) + Table 9 (C4 PPL), weight-only ----------------

fn table1_and_9(ctx: &Ctx) -> Result<()> {
    let sizes: Vec<&str> = if ctx.fast { vec!["tiny"] } else { vec!["tiny", "small"] };
    let configs: Vec<QuantConfig> = if ctx.fast {
        vec![
            QuantConfig::weight_only(2, GroupScheme::Group(64)),
            QuantConfig::weight_only(3, GroupScheme::Group(128)),
        ]
    } else {
        vec![
            QuantConfig::weight_only(2, GroupScheme::PerChannel),
            QuantConfig::weight_only(2, GroupScheme::Group(128)),
            QuantConfig::weight_only(2, GroupScheme::Group(64)),
            QuantConfig::weight_only(3, GroupScheme::PerChannel),
            QuantConfig::weight_only(3, GroupScheme::Group(128)),
            QuantConfig::weight_only(4, GroupScheme::PerChannel),
        ]
    };
    let mut headers = vec!["Config".to_string(), "Method".to_string()];
    headers.extend(sizes.iter().map(|s| s.to_string()));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t1 = Table::new("Table 1: weight-only quantization, wiki-like PPL", &hdr);
    let mut t9 = Table::new("Table 9: weight-only quantization, c4-like PPL", &hdr);

    // FP16 row
    let mut fp_wiki = vec!["FP16".to_string(), "-".to_string()];
    let mut fp_c4 = fp_wiki.clone();
    for size in &sizes {
        let base = ctx.base_model(size, CorpusKind::WikiLike)?;
        let q = Quantized { params: base, head_t: None, report: None };
        let qcfg = QuantConfig::weight_only(16, GroupScheme::PerChannel);
        let e = evaluate(ctx, size, &q, &qcfg, false)?;
        fp_wiki.push(fmt_ppl(e.ppl_wiki));
        fp_c4.push(fmt_ppl(e.ppl_c4));
    }
    t1.row(fp_wiki);
    t9.row(fp_c4);

    for qcfg in &configs {
        // paper: W2 per-channel rows init TesseraQ from OmniQuant clips
        let tq = if qcfg.w_bits == 2 && qcfg.scheme == GroupScheme::PerChannel {
            Method::TesseraQLwc
        } else {
            Method::TesseraQ
        };
        let methods: Vec<Method> = if ctx.fast {
            vec![Method::Rtn, Method::Awq, Method::OmniQuant, tq]
        } else {
            vec![Method::Rtn, Method::Gptq, Method::Awq, Method::OmniQuant, tq]
        };
        for m in methods {
            let mut row_w = vec![qcfg.label(), m.label().to_string()];
            let mut row_c = row_w.clone();
            for size in &sizes {
                let base = ctx.base_model(size, CorpusKind::WikiLike)?;
                let calib = ctx.corpus(CorpusKind::WikiLike, size)?;
                let q = run_method(ctx, &base, m, qcfg, &calib)?;
                let e = evaluate(ctx, size, &q, qcfg, false)?;
                row_w.push(fmt_ppl(e.ppl_wiki));
                row_c.push(fmt_ppl(e.ppl_c4));
            }
            t1.row(row_w);
            t9.row(row_c);
        }
    }
    t1.emit("table1_weight_only_ppl")?;
    t9.emit("table9_c4_ppl")?;
    Ok(())
}

// -- Table 2: zero-shot accuracy, weight-only --------------------------------

fn table2(ctx: &Ctx) -> Result<()> {
    let sizes: Vec<&str> = if ctx.fast { vec!["tiny"] } else { vec!["tiny", "small"] };
    let configs = [
        QuantConfig::weight_only(2, GroupScheme::Group(128)),
        QuantConfig::weight_only(3, GroupScheme::Group(128)),
    ];
    let mut t = Table::new(
        "Table 2: weight-only zero-shot accuracy (5 synthetic tasks)",
        &["Model", "Bitwidth", "Method", "PiQA-s", "ArcE-s", "ArcC-s", "Hella-s", "Wino-s", "Avg"],
    );
    for size in &sizes {
        let base = ctx.base_model(size, CorpusKind::WikiLike)?;
        let calib = ctx.corpus(CorpusKind::C4Like, size)?; // paper: C4 calib for tasks
        // FP16 row
        let qfp = QuantConfig::weight_only(16, GroupScheme::PerChannel);
        let e = evaluate(
            ctx,
            size,
            &Quantized { params: base.clone(), head_t: None, report: None },
            &qfp,
            true,
        )?;
        let mut row = vec![size.to_string(), "FP16".into(), "-".into()];
        row.extend(e.accs.iter().map(|(_, a)| fmt_acc(*a)));
        t.row(row);
        for qcfg in &configs {
            let methods: Vec<Method> = if ctx.fast {
                vec![Method::Awq, Method::TesseraQ]
            } else {
                vec![Method::Gptq, Method::Awq, Method::OmniQuant, Method::TesseraQ]
            };
            for m in methods {
                let q = run_method(ctx, &base, m, qcfg, &calib)?;
                let e = evaluate(ctx, size, &q, qcfg, true)?;
                let mut row = vec![size.to_string(), qcfg.label(), m.label().to_string()];
                row.extend(e.accs.iter().map(|(_, a)| fmt_acc(*a)));
                t.row(row);
            }
        }
    }
    t.emit("table2_zeroshot")?;
    Ok(())
}

// -- Table 3 (+12): W4A4 / W3A3 with rotation --------------------------------

fn table3(ctx: &Ctx) -> Result<()> {
    let size = "tiny";
    let base = ctx.base_model(size, CorpusKind::WikiLike)?;
    let calib = ctx.corpus(CorpusKind::WikiLike, size)?;
    let configs = [
        QuantConfig::new(4, GroupScheme::PerChannel, Some(4)),
        QuantConfig::new(3, GroupScheme::PerChannel, Some(3)),
    ];
    let mut t = Table::new(
        "Table 3: weight-activation quantization (per-channel W, per-token A)",
        &["Bitwidth", "Method", "WT2", "C4", "Avg acc"],
    );
    let qfp = QuantConfig::weight_only(16, GroupScheme::PerChannel);
    let e = evaluate(
        ctx,
        size,
        &Quantized { params: base.clone(), head_t: None, report: None },
        &qfp,
        true,
    )?;
    t.row(vec!["FP16".into(), "-".into(), fmt_ppl(e.ppl_wiki), fmt_ppl(e.ppl_c4),
               fmt_acc(avg_acc(&e.accs))]);
    for qcfg in &configs {
        let methods: Vec<Method> = if ctx.fast {
            vec![Method::SmoothQuant, Method::TesseraQ, Method::QuaRotGptq,
                 Method::QuaRotTesseraQ]
        } else {
            vec![Method::SmoothQuant, Method::Awq, Method::TesseraQ, Method::QuaRot,
                 Method::QuaRotGptq, Method::QuaRotTesseraQ]
        };
        for m in methods {
            let q = run_method(ctx, &base, m, qcfg, &calib)?;
            let e = evaluate(ctx, size, &q, qcfg, true)?;
            t.row(vec![qcfg.label(), m.label().to_string(), fmt_ppl(e.ppl_wiki),
                       fmt_ppl(e.ppl_c4), fmt_acc(avg_acc(&e.accs))]);
        }
    }
    t.emit("table3_wa_quant")?;
    Ok(())
}

// -- Table 4: edge-size models ------------------------------------------------

fn table4(ctx: &Ctx) -> Result<()> {
    let mut t = Table::new(
        "Table 4: edge-size models (nano ~ LLaMA-3.2-1B stand-in)",
        &["Model", "Bitwidth", "Method", "WT2", "Avg acc"],
    );
    let cases: Vec<(&str, GroupScheme)> = vec![
        ("nano", GroupScheme::Group(32)),
        ("tiny", GroupScheme::Group(128)),
    ];
    for (size, scheme) in cases {
        let base = ctx.base_model(size, CorpusKind::WikiLike)?;
        let calib = ctx.corpus(CorpusKind::WikiLike, size)?;
        let qfp = QuantConfig::weight_only(16, GroupScheme::PerChannel);
        let e = evaluate(
            ctx,
            size,
            &Quantized { params: base.clone(), head_t: None, report: None },
            &qfp,
            true,
        )?;
        t.row(vec![size.into(), "FP16".into(), "-".into(), fmt_ppl(e.ppl_wiki),
                   fmt_acc(avg_acc(&e.accs))]);
        let bits: Vec<u32> = if ctx.fast { vec![2, 4] } else { vec![2, 3, 4] };
        for b in bits {
            let qcfg = QuantConfig::weight_only(b, scheme);
            for m in [Method::Awq, Method::TesseraQ] {
                let q = run_method(ctx, &base, m, &qcfg, &calib)?;
                let e = evaluate(ctx, size, &q, &qcfg, true)?;
                t.row(vec![size.into(), qcfg.label(), m.label().to_string(),
                           fmt_ppl(e.ppl_wiki), fmt_acc(avg_acc(&e.accs))]);
            }
        }
    }
    t.emit("table4_edge")?;
    Ok(())
}

// -- Table 5: calibration data source / size / batch ablation ----------------

fn table5(ctx: &Ctx) -> Result<()> {
    let size = "tiny";
    let qcfg = QuantConfig::weight_only(2, GroupScheme::Group(128));
    let mut t = Table::new(
        "Table 5: calibration source / #samples / batch ablation (W2A16g128)",
        &["#Samples", "BS", "Calib", "WT2", "C4", "Avg acc", "Runtime(s)"],
    );
    let base = ctx.base_model(size, CorpusKind::WikiLike)?;
    let sample_sets: Vec<(usize, usize, &str)> = if ctx.fast {
        vec![(8, 1, ".b1"), (16, 4, "")]
    } else {
        vec![(8, 1, ".b1"), (16, 2, ".b2"), (32, 2, ".b2"), (32, 4, "")]
    };
    for kind in [CorpusKind::WikiLike, CorpusKind::C4Like] {
        let calib = ctx.corpus(kind, size)?;
        for &(n_seq, bs, suffix) in &sample_sets {
            let mut opts = MethodOpts::new(qcfg, n_seq, ctx.fast);
            opts.robust = ctx.robust.clone();
            opts.tesseraq.artifact_suffix = suffix.to_string();
            crate::obs::warn(
                "warn",
                &format!("[table5] {} n={} bs={}", kind.name(), n_seq, bs),
                &[("calib", kind.name().into()), ("n_seq", n_seq.into()), ("bs", bs.into())],
            );
            let q = quantize(&ctx.eng, &base, Method::TesseraQ, &qcfg, &calib, &opts)?;
            emit_calib_json(
                &format!("calib_table5_{}_n{}_b{}", kind.name(), n_seq, bs),
                q.report.as_ref(),
            );
            let e = evaluate(ctx, size, &q, &qcfg, true)?;
            let wall = q.report.as_ref().map(|r| r.wall_s).unwrap_or(f64::NAN);
            t.row(vec![n_seq.to_string(), bs.to_string(), kind.name().into(),
                       fmt_ppl(e.ppl_wiki), fmt_ppl(e.ppl_c4),
                       fmt_acc(avg_acc(&e.accs)), format!("{wall:.1}")]);
        }
    }
    t.emit("table5_calib_ablation")?;
    Ok(())
}

// -- Table 6: PAR / DST ablation ----------------------------------------------

fn table6(ctx: &Ctx) -> Result<()> {
    let size = "tiny";
    let qcfg = QuantConfig::weight_only(2, GroupScheme::Group(128));
    let base = ctx.base_model(size, CorpusKind::WikiLike)?;
    let calib = ctx.corpus(CorpusKind::WikiLike, size)?;
    let mut t = Table::new(
        "Table 6: TesseraQ algorithm choices (W2A16g128)",
        &["PAR", "DST", "WT2", "C4", "Avg acc"],
    );
    for (par, dst) in [(false, false), (true, false), (false, true), (true, true)] {
        let q = if !par && !dst {
            // row 1 of the paper's table is the AWQ baseline
            run_method(ctx, &base, Method::Awq, &qcfg, &calib)?
        } else {
            let mut opts = MethodOpts::new(qcfg, ctx.n_calib(), ctx.fast);
            opts.robust = ctx.robust.clone();
            opts.tesseraq.enable_par = par;
            opts.tesseraq.enable_dst = dst;
            let q = quantize(&ctx.eng, &base, Method::TesseraQ, &qcfg, &calib, &opts)?;
            emit_calib_json(
                &format!("calib_table6_par{}_dst{}", par as u8, dst as u8),
                q.report.as_ref(),
            );
            q
        };
        let e = evaluate(ctx, size, &q, &qcfg, true)?;
        let onoff = |b: bool| if b { "yes" } else { "no" }.to_string();
        t.row(vec![onoff(par), onoff(dst), fmt_ppl(e.ppl_wiki), fmt_ppl(e.ppl_c4),
                   fmt_acc(avg_acc(&e.accs))]);
    }
    t.emit("table6_par_dst_ablation")?;
    Ok(())
}

// -- Table 7: flipped rounding variables --------------------------------------

fn table7(ctx: &Ctx) -> Result<()> {
    let size = "tiny";
    let base = ctx.base_model(size, CorpusKind::WikiLike)?;
    let calib = ctx.corpus(CorpusKind::WikiLike, size)?;
    let mut t = Table::new(
        "Table 7: rounding variables flipped by TesseraQ (avg per block)",
        &["Bits", "q_proj", "k_proj", "v_proj", "o_proj", "gate_proj", "up_proj", "down_proj"],
    );
    let bits: Vec<u32> = if ctx.fast { vec![2] } else { vec![2, 4] };
    for b in bits {
        let qcfg = QuantConfig::weight_only(b, GroupScheme::Group(128));
        let q = run_method(ctx, &base, Method::TesseraQ, &qcfg, &calib)?;
        let report =
            q.report.as_ref().context("TesseraQ run produced no calibration report")?;
        let mut row = vec![qcfg.label()];
        for name in crate::model::LINEAR_NAMES {
            let (mut flips, mut total) = (0usize, 0usize);
            for tr in &report.per_block {
                let (f, n) = tr.flips[name];
                flips += f;
                total += n;
            }
            let nb = report.per_block.len();
            row.push(format!("{} ({:.2}%)", flips / nb.max(1),
                             100.0 * flips as f64 / total.max(1) as f64));
        }
        t.row(row);
    }
    t.emit("table7_flips")?;
    Ok(())
}

// -- Table 8: weight memory + serving throughput ------------------------------

fn table8(ctx: &Ctx) -> Result<()> {
    let size = "tiny";
    let base = ctx.base_model(size, CorpusKind::WikiLike)?;
    let calib = ctx.corpus(CorpusKind::WikiLike, size)?;
    let mut t = Table::new(
        "Table 8: weight memory and decode throughput (Rust packed kernels)",
        &["Bitwidth", "Backend", "WM", "TP_1 (tok/s)", "TP_16 (tok/s)"],
    );
    let gen_len = if ctx.fast { 24 } else { 64 };
    let mut serve_rows = |model: &ServeModel, bitlabel: &str, backend: &str| -> Result<()> {
        let p1: Vec<Vec<i32>> = vec![calib.sample(16, 1)];
        let (_, s1) = model.generate(&p1, gen_len)?;
        let p16: Vec<Vec<i32>> = (0..16).map(|i| calib.sample(16, i as u64)).collect();
        let (_, s16) = model.generate(&p16, gen_len)?;
        t.row(vec![bitlabel.into(), backend.into(), fmt_bytes(model.weight_bytes()),
                   format!("{:.1}", s1.tokens_per_s), format!("{:.1}", s16.tokens_per_s)]);
        Ok(())
    };
    let dense = ServeModel::dense(&base);
    serve_rows(&dense, "FP16", "dense f32")?;
    for bits in [4u32, 2] {
        let qcfg = QuantConfig::weight_only(bits, GroupScheme::Group(128));
        let q = run_method(ctx, &base, Method::TesseraQ, &qcfg, &calib)?;
        let report =
            q.report.as_ref().context("TesseraQ run produced no calibration report")?;
        let packed = ServeModel::packed(&q.params, report, bits)?;
        serve_rows(&packed, &qcfg.label(), "packed rust")?;
    }
    t.emit("table8_throughput")?;
    Ok(())
}

// -- Table 10: W4A8 -----------------------------------------------------------

fn table10(ctx: &Ctx) -> Result<()> {
    let mut t = Table::new(
        "Table 10: W4A8 quantization",
        &["Model", "Method", "WT2", "Avg acc"],
    );
    let cases: Vec<(&str, GroupScheme)> = vec![
        ("tiny", GroupScheme::PerChannel),
        ("tiny-gqa", GroupScheme::Group(128)), // gqa artifacts ship g128 only
    ];
    for (size, scheme) in cases {
        let base = ctx.base_model(size, CorpusKind::WikiLike)?;
        let calib = ctx.corpus(CorpusKind::WikiLike, size)?;
        let qcfg = QuantConfig::new(4, scheme, Some(8));
        let methods: Vec<Method> = if ctx.fast {
            vec![Method::SmoothQuant, Method::TesseraQ]
        } else {
            vec![Method::SmoothQuant, Method::Awq, Method::TesseraQ]
        };
        for m in methods {
            let q = run_method(ctx, &base, m, &qcfg, &calib)?;
            let e = evaluate(ctx, size, &q, &qcfg, true)?;
            t.row(vec![size.into(), m.label().to_string(), fmt_ppl(e.ppl_wiki),
                       fmt_acc(avg_acc(&e.accs))]);
        }
    }
    t.emit("table10_w4a8")?;
    Ok(())
}

// -- Table 11: Mistral stand-in (GQA variant) ---------------------------------

fn table11(ctx: &Ctx) -> Result<()> {
    let size = "tiny-gqa";
    let base = ctx.base_model(size, CorpusKind::WikiLike)?;
    let calib = ctx.corpus(CorpusKind::WikiLike, size)?;
    let mut t = Table::new(
        "Table 11: GQA model (Mistral-7B stand-in)",
        &["Bitwidth", "Method", "WT2", "Avg acc"],
    );
    let configs: Vec<QuantConfig> = vec![
        QuantConfig::weight_only(2, GroupScheme::Group(128)),
        QuantConfig::weight_only(3, GroupScheme::Group(128)),
        QuantConfig::new(4, GroupScheme::Group(128), Some(4)),
    ];
    for qcfg in &configs {
        let methods: Vec<Method> = if ctx.fast {
            vec![Method::Awq, Method::TesseraQ]
        } else {
            vec![Method::Gptq, Method::Awq, Method::TesseraQ]
        };
        for m in methods {
            let q = run_method(ctx, &base, m, qcfg, &calib)?;
            let e = evaluate(ctx, size, &q, qcfg, true)?;
            t.row(vec![qcfg.label(), m.label().to_string(), fmt_ppl(e.ppl_wiki),
                       fmt_acc(avg_acc(&e.accs))]);
        }
    }
    t.emit("table11_gqa")?;
    Ok(())
}

// -- Figure 2: TesseraQ vs GPTQ-on-AWQ ----------------------------------------

fn figure2(ctx: &Ctx) -> Result<()> {
    let size = "tiny";
    let base = ctx.base_model(size, CorpusKind::WikiLike)?;
    let calib = ctx.corpus(CorpusKind::WikiLike, size)?;
    let configs: Vec<QuantConfig> = if ctx.fast {
        vec![QuantConfig::weight_only(2, GroupScheme::Group(64))]
    } else {
        vec![
            QuantConfig::weight_only(2, GroupScheme::Group(128)),
            QuantConfig::weight_only(2, GroupScheme::Group(64)),
            QuantConfig::weight_only(3, GroupScheme::Group(128)),
        ]
    };
    let mut t = Table::new(
        "Figure 2 (data): GPTQ-on-AWQ barely helps; TesseraQ does",
        &["Config", "AWQ", "AWQ+GPTQ", "TesseraQ*"],
    );
    for qcfg in &configs {
        let mut row = vec![qcfg.label()];
        for m in [Method::Awq, Method::GptqOnAwq, Method::TesseraQ] {
            let q = run_method(ctx, &base, m, qcfg, &calib)?;
            let e = evaluate(ctx, size, &q, qcfg, false)?;
            row.push(fmt_ppl(e.ppl_wiki));
        }
        t.row(row);
    }
    t.emit("figure2_gptq_on_awq")?;
    Ok(())
}

// -- Figure 3: PAR schedule ablation ------------------------------------------

fn figure3(ctx: &Ctx) -> Result<()> {
    let size = "tiny";
    let base = ctx.base_model(size, CorpusKind::WikiLike)?;
    let calib = ctx.corpus(CorpusKind::WikiLike, size)?;
    let qcfg = QuantConfig::weight_only(2, GroupScheme::Group(128));
    let schedules: Vec<Schedule> = if ctx.fast {
        vec![Schedule::ExpTemp(4.0), Schedule::Handcrafted]
    } else {
        vec![
            Schedule::ExpTemp(2.0), Schedule::ExpTemp(3.0), Schedule::ExpTemp(4.0),
            Schedule::ExpTemp(5.0), Schedule::Handcrafted, Schedule::Linear,
        ]
    };
    let mut t = Table::new(
        "Figure 3 (data): PAR soft-rate schedule ablation (W2A16g128)",
        &["Schedule", "avg PPL", "Avg acc"],
    );
    for sched in schedules {
        let mut opts = MethodOpts::new(qcfg, ctx.n_calib(), ctx.fast);
        opts.robust = ctx.robust.clone();
        opts.schedule = sched;
        let q = quantize(&ctx.eng, &base, Method::TesseraQ, &qcfg, &calib, &opts)?;
        emit_calib_json(&format!("calib_figure3_{}", sched.label()), q.report.as_ref());
        let e = evaluate(ctx, size, &q, &qcfg, true)?;
        t.row(vec![sched.label(), fmt_ppl(0.5 * (e.ppl_wiki + e.ppl_c4)),
                   fmt_acc(avg_acc(&e.accs))]);
    }
    t.emit("figure3_schedules")?;
    Ok(())
}

// -- Figure 4: reconstruction loss convergence --------------------------------

fn figure4(ctx: &Ctx) -> Result<()> {
    let size = "tiny";
    let base = ctx.base_model(size, CorpusKind::WikiLike)?;
    let calib = ctx.corpus(CorpusKind::WikiLike, size)?;
    let qcfg = QuantConfig::weight_only(2, GroupScheme::Group(128));
    let tokens = calib.sequences(ctx.n_calib(), base.cfg.max_seq, 0xCA11B);

    // TesseraQ trace (AWQ init, like the paper's fair comparison)
    let mut p_tq = base.clone();
    let res = crate::baselines::awq::awq_transform(
        &mut p_tq,
        &base.embed(&tokens, ctx.n_calib(), base.cfg.max_seq),
        &qcfg,
        16,
        6,
    );
    let opts = MethodOpts::new(qcfg, ctx.n_calib(), ctx.fast);
    let rep_tq = crate::coordinator::par::calibrate_tesseraq_robust(
        Some(&ctx.eng), &mut p_tq, Some(&res.clips), &tokens, ctx.n_calib(),
        &opts.tesseraq, &ctx.robust,
    )?;

    // OmniQuant-LWC trace on the same init
    let mut p_lwc = base.clone();
    let rep_lwc = crate::coordinator::lwc::calibrate_lwc_robust(
        Some(&ctx.eng), &mut p_lwc, &tokens, ctx.n_calib(), &opts.lwc, &ctx.robust,
    )?;
    emit_calib_json("calib_figure4_tesseraq", Some(&rep_tq));
    emit_calib_json("calib_figure4_omniquant", Some(&rep_lwc.calib));

    let mut t = Table::new(
        "Figure 4 (data): final block reconstruction loss per block",
        &["Block", "TesseraQ final", "OmniQuant final"],
    );
    let mut csv = String::from("block,step,tesseraq,omniquant\n");
    for (l, (tr, lw)) in rep_tq.per_block.iter().zip(&rep_lwc.losses).enumerate() {
        let n = tr.losses.len().max(lw.len());
        for s in 0..n {
            let a = tr.losses.get(s).map(|v| v.to_string()).unwrap_or_default();
            let b = lw.get(s).map(|v| v.to_string()).unwrap_or_default();
            csv.push_str(&format!("{l},{s},{a},{b}\n"));
        }
        // a fallback block has no soften losses; print NaN rather than panic
        t.row(vec![
            l.to_string(),
            format!("{:.5}", tr.losses.last().copied().unwrap_or(f32::NAN)),
            format!("{:.5}", lw.last().copied().unwrap_or(f32::NAN)),
        ]);
    }
    std::fs::create_dir_all(crate::report::results_dir())?;
    std::fs::write(crate::report::results_dir().join("figure4_losses.csv"), csv)?;
    t.emit("figure4_convergence")?;
    append_log(
        "figure4_convergence.md",
        "\nFull per-step traces: results/figure4_losses.csv\n",
    )?;
    Ok(())
}
