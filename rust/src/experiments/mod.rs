//! Experiment drivers: one function per paper table/figure (DESIGN.md §5).
//!
//! Each driver assembles the full pipeline — pretrained checkpoint,
//! baseline/TesseraQ quantization, evaluation — and prints/persists a
//! paper-shaped Markdown table under results/. `fast` shrinks calibration
//! budgets and method sets for CI-speed runs; the full configuration is
//! what EXPERIMENTS.md records.

pub mod methods;
pub mod tables;

use anyhow::Result;

use crate::coordinator::pretrain::{pretrain, PretrainConfig};
use crate::data::{Corpus, CorpusKind};
use crate::model::{ModelConfig, Params};
use crate::report::results_dir;
use crate::robust::RobustConfig;
use crate::runtime::Engine;
use crate::tensor::Pcg32;

pub struct Ctx {
    pub eng: Engine,
    pub fast: bool,
    /// Resilience knobs threaded into every reconstruction calibration a
    /// table/figure runs (checkpoint/resume via `--checkpoint-dir` /
    /// `--resume`, fault injection via `--inject-faults`).
    pub robust: RobustConfig,
}

impl Ctx {
    pub fn new(fast: bool) -> Result<Ctx> {
        Ok(Ctx { eng: Engine::from_default_dir()?, fast, robust: RobustConfig::default() })
    }

    /// Pretraining steps per model size (fast mode trains less).
    fn steps_for(&self, size: &str) -> usize {
        let base = match size {
            "nano" => 120,
            "tiny" | "tiny-gqa" => 300,
            _ => 240,
        };
        if self.fast {
            base / 4
        } else {
            base
        }
    }

    /// Load or pretrain a checkpoint for (size, corpus); cached on disk so
    /// every table shares the same base model.
    pub fn base_model(&self, size: &str, kind: CorpusKind) -> Result<Params> {
        let dir = results_dir().join("ckpt");
        let tag = if self.fast { "fast" } else { "full" };
        let path = dir.join(format!("{size}.{}.{tag}.tsq", kind.name()));
        if path.exists() {
            if let Ok(p) = Params::load(&path) {
                return Ok(p);
            }
        }
        let cfg = ModelConfig::preset(size)?;
        let corpus = Corpus::new(kind, cfg.vocab_size);
        let mut rng = Pcg32::seeded(42);
        let mut params = Params::init(&cfg, &mut rng);
        let pcfg = PretrainConfig {
            steps: self.steps_for(size),
            ..PretrainConfig::default()
        };
        eprintln!("[pretrain] {size} on {} for {} steps...", kind.name(), pcfg.steps);
        pretrain(&self.eng, &mut params, &corpus, &pcfg, |s, l| {
            eprintln!("  step {s:>4}  loss {l:.4}");
        })?;
        params.save(&path)?;
        Ok(params)
    }

    pub fn corpus(&self, kind: CorpusKind, size: &str) -> Result<Corpus> {
        let cfg = ModelConfig::preset(size)?;
        Ok(Corpus::new(kind, cfg.vocab_size))
    }

    /// Calibration sequence count (paper: 512 x 2048 tokens; scaled).
    pub fn n_calib(&self) -> usize {
        if self.fast {
            16
        } else {
            32
        }
    }

    /// Held-out evaluation sequences.
    pub fn n_eval(&self) -> usize {
        if self.fast {
            24
        } else {
            64
        }
    }

    /// Zero-shot items per task.
    pub fn n_items(&self) -> usize {
        if self.fast {
            60
        } else {
            200
        }
    }
}
