//! Scheduling primitives for the serving gateway: admission control,
//! typed per-request errors and outcomes, KV-slot accounting, the
//! packed-path circuit breaker, and the gateway clock.
//!
//! Everything here is deterministic and allocation-light; the policy
//! lives in [`super::gateway`], these are the mechanism types. The
//! gateway clock mixes real wall time with *synthetic* milliseconds
//! added by injected faults (slow decode steps, queue stalls), so chaos
//! drills can force deadline behavior deterministically: tests use
//! synthetic delays orders of magnitude above real step time, and the
//! outcome can never flip on scheduler jitter.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::robust::RetryPolicy;

/// Typed serving-path errors. These are *row-level* failures: one
/// request failing must never take down its batchmates, so the gateway
/// surfaces them per request instead of bubbling a batch-wide `anyhow`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The row's logits contained NaN/Inf (or were empty) at `step`
    /// (the request's own 1-based step, prefill included). The old path
    /// silently decoded token 0 here.
    PoisonedLogits { row: usize, step: usize },
    /// The KV cache would need `need` slots but is capped at
    /// `max_slots`; growth is refused instead of reallocating without
    /// bound.
    KvCapacity { need: usize, max_slots: usize },
    /// The serving session was aborted (injected kill / engine crash)
    /// and the request had already burned its requeue budget.
    SessionAborted,
    /// The degraded dense-path retry also failed; the message carries
    /// the final retry error.
    FallbackFailed(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::PoisonedLogits { row, step } => {
                write!(f, "non-finite logits for row {row} at step {step}")
            }
            ServeError::KvCapacity { need, max_slots } => {
                write!(f, "KV cache needs {need} slots, capped at {max_slots}")
            }
            ServeError::SessionAborted => write!(f, "serving session aborted"),
            ServeError::FallbackFailed(e) => write!(f, "dense fallback failed: {e}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Why a request was refused at the door. Shedding is load *control*,
/// not failure: the caller gets the reason synchronously and can back
/// off, retry elsewhere, or shrink the request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShedReason {
    /// The bounded admission queue is at `queue_depth`.
    QueueFull { depth: usize },
    /// `prompt_len + max_new` can never fit the per-session KV budget;
    /// admitting it would OOM mid-flight, so it is refused up front.
    KvBudget { need: usize, budget: usize },
    /// Empty prompt or token id outside the model vocabulary.
    InvalidPrompt(String),
}

impl ShedReason {
    /// Stable tag for telemetry events.
    pub fn tag(&self) -> &'static str {
        match self {
            ShedReason::QueueFull { .. } => "queue_full",
            ShedReason::KvBudget { .. } => "kv_budget",
            ShedReason::InvalidPrompt(_) => "invalid_prompt",
        }
    }
}

impl std::fmt::Display for ShedReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShedReason::QueueFull { depth } => write!(f, "admission queue full ({depth})"),
            ShedReason::KvBudget { need, budget } => {
                write!(f, "request needs {need} KV slots, session budget is {budget}")
            }
            ShedReason::InvalidPrompt(m) => write!(f, "invalid prompt: {m}"),
        }
    }
}

/// Where a deadline was missed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeadlineStage {
    /// Expired while still waiting in the admission queue.
    Queue,
    /// Evicted mid-batch during decode.
    Decode,
}

impl DeadlineStage {
    pub fn tag(&self) -> &'static str {
        match self {
            DeadlineStage::Queue => "queue",
            DeadlineStage::Decode => "decode",
        }
    }
}

/// Terminal state of an *admitted* request. Request conservation (the
/// chaos-drill invariant) says every admitted request reaches exactly
/// one of these; shed requests are refused before admission and never
/// get an outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum RequestOutcome {
    Completed {
        tokens: Vec<i32>,
        /// Submit-to-completion latency on the gateway clock.
        latency_ms: u64,
        /// Served by the dense fallback after a packed-path failure.
        degraded: bool,
    },
    DeadlineMissed {
        /// Tokens generated before eviction (discarded output).
        generated: usize,
        stage: DeadlineStage,
    },
    Failed(ServeError),
}

/// One generation request as submitted by a client.
#[derive(Debug, Clone)]
pub struct Request {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Latency budget in milliseconds from submission; `None` falls
    /// back to the gateway's `default_deadline_ms` (which may itself be
    /// `None` = no deadline).
    pub deadline_ms: Option<u64>,
}

impl Request {
    pub fn new(prompt: Vec<i32>, max_new: usize) -> Request {
        Request { prompt, max_new, deadline_ms: None }
    }

    pub fn with_deadline(mut self, ms: u64) -> Request {
        self.deadline_ms = Some(ms);
        self
    }

    /// KV slots this request can consume: one per prompt token plus one
    /// per generated token.
    pub fn kv_slots(&self) -> usize {
        self.prompt.len() + self.max_new
    }
}

/// Gateway knobs. Defaults are sized for the test presets; production
/// callers set all of them explicitly.
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Bounded admission queue depth; submissions beyond it are shed.
    pub queue_depth: usize,
    /// Batch width: concurrent rows in one serving session.
    pub max_batch: usize,
    /// Shared-time-axis KV slot cap per session. Admission guarantees
    /// `cache.len + prompt_len + max_new <= budget` for every joining
    /// row, so the cache can never OOM mid-flight.
    pub kv_slot_budget: usize,
    /// Deadline applied to requests that carry none.
    pub default_deadline_ms: Option<u64>,
    /// Consecutive packed-path row failures before the breaker trips
    /// and the whole gateway degrades to the dense fallback; 0 disables
    /// the breaker (per-request fallback still applies).
    pub breaker_threshold: u32,
    /// Retry policy for the degraded dense-path re-run of a failed
    /// request.
    pub retry: RetryPolicy,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            queue_depth: 32,
            max_batch: 4,
            kv_slot_budget: 4096,
            default_deadline_ms: None,
            breaker_threshold: 3,
            retry: RetryPolicy::immediate(2),
        }
    }
}

/// Monotonic gateway time: real wall time plus synthetic milliseconds
/// injected by faults. All deadlines, queue ages, and latency
/// histograms read this clock, so a chaos drill advancing it by 10^7 ms
/// produces the same evictions on any machine.
#[derive(Debug)]
pub struct GatewayClock {
    t0: Instant,
    synthetic_ms: u64,
}

impl Default for GatewayClock {
    fn default() -> Self {
        GatewayClock { t0: Instant::now(), synthetic_ms: 0 }
    }
}

impl GatewayClock {
    pub fn now_ms(&self) -> u64 {
        (self.t0.elapsed().as_millis() as u64).saturating_add(self.synthetic_ms)
    }

    /// Add synthetic time (injected slow step / queue stall, or the
    /// open-loop generator skipping ahead to the next arrival).
    pub fn advance_ms(&mut self, ms: u64) {
        self.synthetic_ms = self.synthetic_ms.saturating_add(ms);
    }
}

/// KV slot accounting: every admitted-to-session request reserves
/// `prompt_len + max_new` slot units, released on its terminal state.
/// After a full drain `in_use() == 0` — the chaos drill's "no KV slots
/// leak" check.
#[derive(Debug, Default)]
pub struct KvLedger {
    reserved: BTreeMap<u64, usize>,
    in_use: usize,
    peak: usize,
}

impl KvLedger {
    pub fn reserve(&mut self, id: u64, slots: usize) {
        debug_assert!(!self.reserved.contains_key(&id), "double reserve for {id}");
        self.reserved.insert(id, slots);
        self.in_use += slots;
        self.peak = self.peak.max(self.in_use);
    }

    /// Release `id`'s reservation; returns the freed slots (0 if it
    /// held none — release is idempotent so every terminal path can
    /// call it unconditionally).
    pub fn release(&mut self, id: u64) -> usize {
        let n = self.reserved.remove(&id).unwrap_or(0);
        self.in_use -= n;
        n
    }

    pub fn in_use(&self) -> usize {
        self.in_use
    }

    pub fn peak(&self) -> usize {
        self.peak
    }
}

/// Consecutive-failure circuit breaker for the packed path. A poisoned
/// row on the packed model counts as a failure; a packed request
/// completing cleanly resets the streak. Once tripped it stays tripped
/// (the operator resets by restarting the gateway): flapping between a
/// kernel that is actively emitting NaNs and back is worse than serving
/// dense until someone looks at it.
#[derive(Debug)]
pub struct Breaker {
    threshold: u32,
    consecutive: u32,
    tripped: bool,
}

impl Breaker {
    pub fn new(threshold: u32) -> Breaker {
        Breaker { threshold, consecutive: 0, tripped: false }
    }

    /// Record a packed-path row failure; returns true iff this failure
    /// trips the breaker (exactly once).
    pub fn record_failure(&mut self) -> bool {
        self.consecutive += 1;
        if !self.tripped && self.threshold > 0 && self.consecutive >= self.threshold {
            self.tripped = true;
            return true;
        }
        false
    }

    pub fn record_success(&mut self) {
        self.consecutive = 0;
    }

    pub fn is_tripped(&self) -> bool {
        self.tripped
    }
}

/// Monotone gateway counters; the conservation test checks
/// `admitted == completed + deadline_missed + failed` after a drain.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct GatewayCounters {
    pub submitted: u64,
    pub admitted: u64,
    pub shed: u64,
    pub completed: u64,
    pub deadline_missed: u64,
    pub failed: u64,
    /// Completions served by the dense fallback.
    pub degraded: u64,
    /// Requests returned to the queue by a session abort.
    pub requeued: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_reserve_release_balances() {
        let mut l = KvLedger::default();
        l.reserve(1, 10);
        l.reserve(2, 5);
        assert_eq!(l.in_use(), 15);
        assert_eq!(l.peak(), 15);
        assert_eq!(l.release(1), 10);
        assert_eq!(l.release(1), 0, "release must be idempotent");
        assert_eq!(l.release(2), 5);
        assert_eq!(l.in_use(), 0);
        assert_eq!(l.peak(), 15);
    }

    #[test]
    fn breaker_trips_on_consecutive_failures_only() {
        let mut b = Breaker::new(3);
        assert!(!b.record_failure());
        assert!(!b.record_failure());
        b.record_success(); // streak broken
        assert!(!b.record_failure());
        assert!(!b.record_failure());
        assert!(b.record_failure(), "third consecutive failure must trip");
        assert!(b.is_tripped());
        assert!(!b.record_failure(), "trip fires exactly once");
        // threshold 0 never trips
        let mut off = Breaker::new(0);
        for _ in 0..10 {
            assert!(!off.record_failure());
        }
        assert!(!off.is_tripped());
    }

    #[test]
    fn clock_synthetic_time_accumulates() {
        let mut c = GatewayClock::default();
        let t = c.now_ms();
        c.advance_ms(1000);
        c.advance_ms(250);
        assert!(c.now_ms() >= t + 1250);
    }

    #[test]
    fn serve_error_displays_and_converts() {
        let e = ServeError::PoisonedLogits { row: 2, step: 7 };
        let a: anyhow::Error = e.clone().into();
        assert!(format!("{a:#}").contains("row 2"));
        assert_eq!(a.downcast_ref::<ServeError>(), Some(&e));
        let s = ShedReason::KvBudget { need: 100, budget: 64 };
        assert_eq!(s.tag(), "kv_budget");
        assert!(format!("{s}").contains("100"));
    }

    #[test]
    fn request_kv_slots() {
        let r = Request::new(vec![1, 2, 3], 5).with_deadline(100);
        assert_eq!(r.kv_slots(), 8);
        assert_eq!(r.deadline_ms, Some(100));
    }
}
