//! Quantized serving path (Table 8): batched greedy decoding with a KV
//! cache over packed INT{2,3,4} weights (Rust-native fused dequant
//! kernels, quant::pack) or dense f32 weights (the FP16-equivalent
//! baseline). Reports weight memory and prefill/decode throughput.
//!
//! Ragged batches are first-class: the KV cache keeps a per-row validity
//! mask and per-row positions, so a short prompt decodes exactly the same
//! tokens whether it is served solo or padded alongside longer batchmates
//! (see README "Serving" for the layout and masking contract).

pub mod gateway;
pub mod sched;

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

pub use gateway::Gateway;
pub use sched::{
    GatewayConfig, GatewayCounters, Request, RequestOutcome, ServeError, ShedReason,
};

use crate::coordinator::par::CalibReport;
use crate::model::hostfwd::{rmsnorm_rows, silu, LinearOp};
use crate::model::{ModelConfig, Params, LINEAR_NAMES};
use crate::quant::pack::PackedLinear;
use crate::tensor::{linalg, Tensor};
use crate::util::parallel_chunks;

/// A servable model: embedding + per-block linear ops (dense or packed).
pub struct ServeModel {
    pub cfg: ModelConfig,
    pub emb: Tensor,
    pub norm_f: Tensor,
    pub blocks: Vec<ServeBlock>,
    pub label: String,
}

pub struct ServeBlock {
    pub linears: BTreeMap<String, Box<dyn LinearOp>>,
    pub norm1: Tensor,
    pub norm2: Tensor,
}

impl ServeModel {
    /// Dense (FP16-equivalent) serving model from parameters.
    pub fn dense(params: &Params) -> ServeModel {
        let cfg = params.cfg.clone();
        let blocks = (0..cfg.n_layers)
            .map(|l| {
                let bv = params.block(l);
                let linears: BTreeMap<String, Box<dyn LinearOp>> = bv
                    .linears
                    .iter()
                    .map(|(k, v)| (k.clone(), Box::new(v.clone()) as Box<dyn LinearOp>))
                    .collect();
                ServeBlock { linears, norm1: bv.norm1, norm2: bv.norm2 }
            })
            .collect();
        ServeModel {
            cfg: cfg.clone(),
            emb: params.get("emb").clone(),
            norm_f: params.get("norm_f").clone(),
            blocks,
            label: "FP16".into(),
        }
    }

    /// Packed model from a TesseraQ calibration report (codes + effective
    /// scales). Embedding and norms stay dense, like the paper. Fails with
    /// context if the report is missing blocks/linears (e.g. built from a
    /// partial calibration) or if codes overflow `bits`.
    pub fn packed(params: &Params, report: &CalibReport, bits: u32) -> Result<ServeModel> {
        let cfg = params.cfg.clone();
        if report.quantized.len() < cfg.n_layers {
            bail!(
                "calibration report covers {} blocks, model has {} — partial run?",
                report.quantized.len(),
                cfg.n_layers
            );
        }
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let bv = params.block(l);
            let mut linears: BTreeMap<String, Box<dyn LinearOp>> = BTreeMap::new();
            for name in LINEAR_NAMES {
                let (codes, qp) = report.quantized[l].get(name).with_context(|| {
                    format!("calibration report block {l} has no codes for {name:?}")
                })?;
                let (o, i) = cfg.linear_shape(name);
                let pl = PackedLinear::from_codes(codes, o, i, bits, qp.clone())
                    .with_context(|| format!("packing block {l} {name}"))?;
                linears.insert(name.to_string(), Box::new(pl) as Box<dyn LinearOp>);
            }
            blocks.push(ServeBlock { linears, norm1: bv.norm1, norm2: bv.norm2 });
        }
        Ok(ServeModel {
            cfg: cfg.clone(),
            emb: params.get("emb").clone(),
            norm_f: params.get("norm_f").clone(),
            blocks,
            label: format!("W{bits} packed"),
        })
    }

    /// Packed model quantized host-side with plain RTN — no calibration
    /// artifacts or engine needed. This is the `repro serve-bench` path:
    /// kernel throughput does not depend on how the codes were chosen, so
    /// a CI box without compiled artifacts can still measure the packed
    /// hot path. Group size per linear: the largest power of two <= 128
    /// dividing its input features.
    pub fn packed_rtn(params: &Params, bits: u32) -> Result<ServeModel> {
        use crate::quant::{minmax_scale, rtn_codes, ClipFactors};
        let cfg = params.cfg.clone();
        let qmax = (2u32.pow(bits) - 1) as f32;
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let bv = params.block(l);
            let mut linears: BTreeMap<String, Box<dyn LinearOp>> = BTreeMap::new();
            for name in LINEAR_NAMES {
                let w = &bv.linears[name];
                let (o, i) = cfg.linear_shape(name);
                let mut g = 128usize;
                while i % g != 0 {
                    g /= 2;
                }
                let qp = minmax_scale(
                    w,
                    g,
                    &ClipFactors::Uniform(1.0),
                    &ClipFactors::Uniform(1.0),
                    qmax,
                );
                let codes = rtn_codes(w, &qp, qmax);
                let pl = PackedLinear::from_codes(&codes, o, i, bits, qp)
                    .with_context(|| format!("packing block {l} {name} (rtn)"))?;
                linears.insert(name.to_string(), Box::new(pl) as Box<dyn LinearOp>);
            }
            blocks.push(ServeBlock { linears, norm1: bv.norm1, norm2: bv.norm2 });
        }
        Ok(ServeModel {
            cfg: cfg.clone(),
            emb: params.get("emb").clone(),
            norm_f: params.get("norm_f").clone(),
            blocks,
            label: format!("W{bits} RTN"),
        })
    }

    /// Weight memory in bytes (Table 8 "WM" column; FP16 reference for
    /// dense tensors).
    pub fn weight_bytes(&self) -> usize {
        let mut n = self.emb.data.len() * 2 + self.norm_f.data.len() * 2;
        for b in &self.blocks {
            n += (b.norm1.data.len() + b.norm2.data.len()) * 2;
            for lin in b.linears.values() {
                n += lin.weight_bytes();
            }
        }
        n
    }
}

/// KV cache for one decode session.
///
/// Layout: `k[layer]` / `v[layer]` are flat `[t][b][d_kv]` buffers
/// (time-major so one decode step appends a single contiguous `[b][d_kv]`
/// slab), preallocated to a slot capacity — the steady-state decode loop
/// never reallocates. Ragged batches share the time axis: slot `t` holds
/// row `r`'s token only if `valid[t * b + r]`; padded slots stay in the
/// buffers but are masked out of every attention softmax, and `row_pos[r]`
/// tracks each row's own token count (== its next RoPE position), which
/// is what keeps a short row's math identical to a solo run.
pub struct KvCache {
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    /// Cache slots filled so far (shared time axis, includes padding).
    pub len: usize,
    cap: usize,
    /// Hard slot ceiling: growth past this returns a typed
    /// `ServeError::KvCapacity` instead of reallocating without bound.
    max_slots: usize,
    b: usize,
    d_kv: usize,
    /// `valid[slot * b + r]`: slot holds a real (non-padding) token of row r.
    valid: Vec<bool>,
    /// Per-row count of real tokens == that row's next RoPE position.
    row_pos: Vec<usize>,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig, b: usize) -> KvCache {
        Self::with_capacity(cfg, b, 16)
    }

    /// Preallocate `cap` cache slots so the decode loop never grows the
    /// buffers. `generate` sizes this as prompt_len + max_new. No slot
    /// ceiling — growth doubles forever (use [`Self::with_limits`] to
    /// cap it).
    pub fn with_capacity(cfg: &ModelConfig, b: usize, cap: usize) -> KvCache {
        Self::with_limits(cfg, b, cap, usize::MAX)
    }

    /// Preallocate `cap` slots with a hard ceiling of `max_slots`: a
    /// decode step that would need slot `max_slots + 1` gets a typed
    /// error instead of an unbounded reallocation. The gateway sizes
    /// this with its KV budget so a runaway session can never OOM the
    /// box.
    pub fn with_limits(cfg: &ModelConfig, b: usize, cap: usize, max_slots: usize) -> KvCache {
        let max_slots = max_slots.max(1);
        let cap = cap.clamp(1, max_slots);
        let d_kv = cfg.d_kv();
        KvCache {
            k: vec![vec![0.0; cap * b * d_kv]; cfg.n_layers],
            v: vec![vec![0.0; cap * b * d_kv]; cfg.n_layers],
            len: 0,
            cap,
            max_slots,
            b,
            d_kv,
            valid: vec![false; cap * b],
            row_pos: vec![0; b],
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn max_slots(&self) -> usize {
        self.max_slots
    }

    /// Row r's own token count (its next RoPE position).
    pub fn row_pos(&self, r: usize) -> usize {
        self.row_pos[r]
    }

    /// Grow to at least `need` slots (doubling; no-op within capacity).
    /// Refuses with `ServeError::KvCapacity` past `max_slots`.
    fn try_reserve(&mut self, need: usize) -> Result<(), ServeError> {
        if need <= self.cap {
            return Ok(());
        }
        if need > self.max_slots {
            return Err(ServeError::KvCapacity { need, max_slots: self.max_slots });
        }
        let cap = need.next_power_of_two().max(self.cap * 2).min(self.max_slots);
        for kl in self.k.iter_mut() {
            kl.resize(cap * self.b * self.d_kv, 0.0);
        }
        for vl in self.v.iter_mut() {
            vl.resize(cap * self.b * self.d_kv, 0.0);
        }
        self.valid.resize(cap * self.b, false);
        self.cap = cap;
        Ok(())
    }

    /// Recycle row `r` for a new session occupant: clear its validity
    /// column (so the newcomer can never attend a previous request's
    /// KV) and reset its RoPE position. The k/v payloads need no
    /// zeroing — masked slots are unreachable by construction. This is
    /// what makes gateway slot reuse bit-exact.
    pub fn reset_row(&mut self, r: usize) {
        for t in 0..self.len {
            self.valid[t * self.b + r] = false;
        }
        self.row_pos[r] = 0;
    }
}

/// Reusable per-session buffers for `decode_step`: activations, q/k/v,
/// attention context, MLP intermediates, logits, and per-worker softmax
/// score slabs. One allocation up front, zero in the steady-state loop.
pub struct DecodeScratch {
    x: Vec<f32>,
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    ctx: Vec<f32>,
    proj: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    mlp: Vec<f32>,
    logits: Vec<f32>,
    scores: Vec<f32>,
    score_cap: usize,
}

impl DecodeScratch {
    pub fn new(cfg: &ModelConfig, b: usize) -> DecodeScratch {
        let d = cfg.d_model;
        let dkv = cfg.d_kv();
        let f = cfg.d_ff;
        DecodeScratch {
            x: vec![0.0; b * d],
            h: vec![0.0; b * d],
            q: vec![0.0; b * d],
            k: vec![0.0; b * dkv],
            v: vec![0.0; b * dkv],
            ctx: vec![0.0; b * d],
            proj: vec![0.0; b * d],
            gate: vec![0.0; b * f],
            up: vec![0.0; b * f],
            mlp: vec![0.0; b * f],
            logits: vec![0.0; b * cfg.vocab_size],
            scores: Vec::new(),
            score_cap: 0,
        }
    }

    /// Size the per-worker softmax slabs for `workers` workers and `t`
    /// cache slots. Grows in power-of-two steps, so a generation session
    /// reallocates O(log t) times, not per step.
    fn ensure_scores(&mut self, workers: usize, t: usize) {
        let cap = t.next_power_of_two();
        if self.score_cap < cap || self.scores.len() < workers * cap {
            self.score_cap = cap;
            self.scores = vec![0.0; workers * cap];
        }
    }
}

/// How `generate` runs the prompt through the model before decoding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefillMode {
    /// One multi-token forward over the whole (padded) prompt batch — the
    /// fast path and the default.
    Batched,
    /// Token-by-token through the decode step — the benchmark baseline
    /// the batched path is measured against.
    PerToken,
}

pub struct DecodeStats {
    pub label: String,
    pub batch: usize,
    /// Longest prompt in the batch (the shared cache prefix length).
    pub prompt_len: usize,
    /// Per-row prompt lengths; differs per row for ragged batches.
    pub prompt_lens: Vec<usize>,
    pub new_tokens: usize,
    /// Prefill wall seconds — recorded separately so `tokens_per_s`
    /// (decode only, the paper's TP_n) is auditable.
    pub prefill_s: f64,
    /// Decode-loop wall seconds.
    pub decode_s: f64,
    /// Generated tokens per second (decode loop only).
    pub tokens_per_s: f64,
    /// Real prompt tokens per second through prefill.
    pub prefill_tokens_per_s: f64,
    pub weight_bytes: usize,
}

/// NaN-aware greedy argmax: `None` if the row is empty or contains any
/// non-finite logit. The old path used a `total_cmp` max with
/// `unwrap_or(0)`, which silently decoded token 0 from poisoned logits —
/// a garbage token indistinguishable from a real one. Ties keep the
/// last maximal index, matching the previous `max_by` behavior exactly
/// for finite inputs.
fn argmax_checked(row: &[f32]) -> Option<i32> {
    let mut best = f32::NEG_INFINITY;
    let mut best_i: Option<i32> = None;
    for (i, &v) in row.iter().enumerate() {
        if !v.is_finite() {
            return None;
        }
        if best_i.is_none() || v >= best {
            best = v;
            best_i = Some(i as i32);
        }
    }
    best_i
}

/// One decode (or prefill) step's per-row results. A poisoned row's
/// token is a placeholder 0 and `poisoned[r]` is set; callers decide
/// whether that fails the row (gateway) or the batch (`generate`).
pub(crate) struct StepOut {
    pub toks: Vec<i32>,
    pub poisoned: Vec<bool>,
}

impl StepOut {
    fn from_logits(
        logits: &mut [f32],
        b: usize,
        v: usize,
        force_poison: Option<&[bool]>,
    ) -> StepOut {
        let mut toks = vec![0i32; b];
        let mut poisoned = vec![false; b];
        for r in 0..b {
            let row = &mut logits[r * v..(r + 1) * v];
            if force_poison.map(|p| p[r]).unwrap_or(false) && !row.is_empty() {
                // fault injection corrupts the real buffer so detection
                // exercises the production argmax path, not a shortcut
                row[0] = f32::NAN;
            }
            match argmax_checked(row) {
                Some(t) => toks[r] = t,
                None => poisoned[r] = true,
            }
        }
        StepOut { toks, poisoned }
    }
}

impl ServeModel {
    /// One decode step for batch `b`: token ids `x_tok` [b] -> greedy
    /// next-token ids [b], appending one slot to the cache.
    /// `step_valid[r]` marks whether row r's token is real; a padding
    /// token's k/v are written but masked out of that row's attention for
    /// the rest of the session, and its `row_pos` does not advance.
    /// `poison[r]` (fault injection) corrupts row r's logits with NaN
    /// before the argmax so the sentinel path is exercised end to end.
    pub(crate) fn decode_step(
        &self,
        x_tok: &[i32],
        step_valid: &[bool],
        cache: &mut KvCache,
        scratch: &mut DecodeScratch,
        poison: Option<&[bool]>,
    ) -> Result<StepOut, ServeError> {
        let cfg = &self.cfg;
        let b = cache.b;
        debug_assert_eq!(x_tok.len(), b);
        debug_assert_eq!(step_valid.len(), b);
        let d = cfg.d_model;
        let slot = cache.len;
        cache.try_reserve(slot + 1)?;
        let t = slot + 1;
        let dkv = cache.d_kv;

        // embed
        for (r, &tok) in x_tok.iter().enumerate() {
            scratch.x[r * d..(r + 1) * d]
                .copy_from_slice(&self.emb.data[tok as usize * d..(tok as usize + 1) * d]);
        }

        let nh = cfg.n_heads;
        let nkv = cfg.n_kv_heads;
        let hd = cfg.head_dim();
        let rep = nh / nkv;
        let scale = 1.0 / (hd as f32).sqrt();
        let workers = crate::util::planned_workers(b * nh);
        scratch.ensure_scores(workers, t);

        // The current slot's validity must be visible to this step's
        // attention: every row attends its own just-written slot, while
        // that row's earlier padding slots stay masked.
        for r in 0..b {
            cache.valid[slot * b + r] = step_valid[r];
        }

        for (l, blk) in self.blocks.iter().enumerate() {
            scratch.h.copy_from_slice(&scratch.x);
            rmsnorm_rows(&mut scratch.h, d, &blk.norm1.data, cfg.norm_eps);
            blk.linears["q_proj"].forward_into(&scratch.h, b, &mut scratch.q);
            blk.linears["k_proj"].forward_into(&scratch.h, b, &mut scratch.k);
            blk.linears["v_proj"].forward_into(&scratch.h, b, &mut scratch.v);
            // RoPE at each row's OWN position (its count of real tokens),
            // not the shared cache slot — this is what makes a short
            // prompt's generation identical to its solo run.
            for r in 0..b {
                let pos = cache.row_pos[r];
                for hi in 0..nh {
                    rope_row(
                        &mut scratch.q[r * d + hi * hd..r * d + (hi + 1) * hd],
                        pos,
                        cfg.rope_theta,
                    );
                }
                for hi in 0..nkv {
                    rope_row(
                        &mut scratch.k[r * dkv + hi * hd..r * dkv + (hi + 1) * hd],
                        pos,
                        cfg.rope_theta,
                    );
                }
            }
            let off = slot * b * dkv;
            cache.k[l][off..off + b * dkv].copy_from_slice(&scratch.k);
            cache.v[l][off..off + b * dkv].copy_from_slice(&scratch.v);

            // attention over the cache, parallel over (row, head) pairs;
            // disjoint raw-pointer writes (hostfwd idiom) into ctx and the
            // per-worker score slabs
            let kl = &cache.k[l];
            let vl = &cache.v[l];
            let valid = &cache.valid;
            let qd: &[f32] = &scratch.q;
            let ctx_ptr = scratch.ctx.as_ptr() as usize;
            let score_cap = scratch.score_cap;
            let scores_ptr = scratch.scores.as_ptr() as usize;
            parallel_chunks(b * nh, |wk, s0, e0| {
                let scores = unsafe {
                    std::slice::from_raw_parts_mut(
                        (scores_ptr as *mut f32).add(wk * score_cap),
                        t,
                    )
                };
                for bh in s0..e0 {
                    let r = bh / nh;
                    let hi = bh % nh;
                    let kvh = hi / rep;
                    let qrow = &qd[r * d + hi * hd..r * d + (hi + 1) * hd];
                    let mut maxv = f32::NEG_INFINITY;
                    for kt in 0..t {
                        if kt != slot && !valid[kt * b + r] {
                            // padding slot for this row: exp(-inf) == 0
                            // removes it from the denominator and the sum
                            scores[kt] = f32::NEG_INFINITY;
                            continue;
                        }
                        let base = (kt * b + r) * dkv + kvh * hd;
                        let dot: f32 = qrow
                            .iter()
                            .zip(&kl[base..base + hd])
                            .map(|(a, c)| a * c)
                            .sum::<f32>()
                            * scale;
                        scores[kt] = dot;
                        maxv = maxv.max(dot);
                    }
                    let mut denom = 0.0f32;
                    for s in scores.iter_mut() {
                        *s = (*s - maxv).exp();
                        denom += *s;
                    }
                    let out = unsafe {
                        std::slice::from_raw_parts_mut(
                            (ctx_ptr as *mut f32).add(r * d + hi * hd),
                            hd,
                        )
                    };
                    out.fill(0.0);
                    for kt in 0..t {
                        let w = scores[kt] / denom;
                        if w == 0.0 {
                            continue;
                        }
                        let base = (kt * b + r) * dkv + kvh * hd;
                        for (o, &vv) in out.iter_mut().zip(&vl[base..base + hd]) {
                            *o += w * vv;
                        }
                    }
                }
            });
            blk.linears["o_proj"].forward_into(&scratch.ctx, b, &mut scratch.proj);
            for (a, o) in scratch.x.iter_mut().zip(&scratch.proj) {
                *a += o;
            }

            scratch.h.copy_from_slice(&scratch.x);
            rmsnorm_rows(&mut scratch.h, d, &blk.norm2.data, cfg.norm_eps);
            blk.linears["gate_proj"].forward_into(&scratch.h, b, &mut scratch.gate);
            blk.linears["up_proj"].forward_into(&scratch.h, b, &mut scratch.up);
            let f = cfg.d_ff;
            for i in 0..b * f {
                scratch.mlp[i] = silu(scratch.gate[i]) * scratch.up[i];
            }
            blk.linears["down_proj"].forward_into(&scratch.mlp, b, &mut scratch.proj);
            for (a, o) in scratch.x.iter_mut().zip(&scratch.proj) {
                *a += o;
            }
        }
        cache.len = t;
        for r in 0..b {
            if step_valid[r] {
                cache.row_pos[r] += 1;
            }
        }

        // head: greedy over the tied embedding
        scratch.h.copy_from_slice(&scratch.x);
        rmsnorm_rows(&mut scratch.h, d, &self.norm_f.data, cfg.norm_eps);
        linalg::matmul_bt_into(
            &scratch.h,
            b,
            d,
            &self.emb.data,
            cfg.vocab_size,
            &mut scratch.logits,
        );
        let v = cfg.vocab_size;
        Ok(StepOut::from_logits(&mut scratch.logits, b, v, poison))
    }

    /// Token-by-token prefill through the decode step (the benchmark
    /// baseline). Rows past their own prompt end feed a masked padding
    /// token; each row's first-generation seed is captured at its OWN
    /// last prompt position.
    fn prefill_per_token(
        &self,
        prompts: &[Vec<i32>],
        plens: &[usize],
        cache: &mut KvCache,
        scratch: &mut DecodeScratch,
    ) -> Result<StepOut, ServeError> {
        let b = prompts.len();
        let tmax = plens.iter().copied().max().unwrap_or(0);
        let mut last = vec![0i32; b];
        // poison status is sampled only at each row's own capture step:
        // intermediate prefill logits are discarded, exactly as in the
        // batched path (which never computes them)
        let mut poisoned = vec![false; b];
        let mut toks = vec![0i32; b];
        let mut valid = vec![false; b];
        for pos in 0..tmax {
            for r in 0..b {
                valid[r] = pos < plens[r];
                toks[r] = if valid[r] { prompts[r][pos] } else { 0 };
            }
            let step = self.decode_step(&toks, &valid, cache, scratch, None)?;
            for r in 0..b {
                if pos + 1 == plens[r] {
                    last[r] = step.toks[r];
                    poisoned[r] = step.poisoned[r];
                }
            }
        }
        Ok(StepOut { toks: last, poisoned })
    }

    /// Batched prefill: one multi-token forward over the padded `[b,
    /// tmax]` prompt batch, filling the KV cache and returning each row's
    /// greedy next token from its OWN last prompt position. During
    /// prefill a row's real tokens are left-aligned, so slot index ==
    /// row position and causal attention needs no extra masking; padded
    /// query slots are skipped outright (their k/v stay masked for the
    /// whole session).
    fn prefill_batched(
        &self,
        prompts: &[Vec<i32>],
        plens: &[usize],
        cache: &mut KvCache,
    ) -> Result<StepOut, ServeError> {
        let cfg = &self.cfg;
        let b = prompts.len();
        let d = cfg.d_model;
        let dkv = cfg.d_kv();
        let f = cfg.d_ff;
        let tmax = plens.iter().copied().max().unwrap_or(0);
        cache.try_reserve(tmax)?;
        let rows = b * tmax;

        let nh = cfg.n_heads;
        let nkv = cfg.n_kv_heads;
        let hd = cfg.head_dim();
        let rep = nh / nkv;
        let scale = 1.0 / (hd as f32).sqrt();

        // embed (padded slots reuse token 0; every later read of them is
        // masked)
        let mut x = vec![0.0f32; rows * d];
        for (r, p) in prompts.iter().enumerate() {
            for pos in 0..tmax {
                let tok = if pos < plens[r] { p[pos] as usize } else { 0 };
                x[(r * tmax + pos) * d..(r * tmax + pos + 1) * d]
                    .copy_from_slice(&self.emb.data[tok * d..(tok + 1) * d]);
            }
        }
        let mut h = vec![0.0f32; rows * d];
        let mut q = vec![0.0f32; rows * d];
        let mut kb = vec![0.0f32; rows * dkv];
        let mut vb = vec![0.0f32; rows * dkv];
        let mut ctx = vec![0.0f32; rows * d];
        let mut proj = vec![0.0f32; rows * d];
        let mut gate = vec![0.0f32; rows * f];
        let mut up = vec![0.0f32; rows * f];
        let mut mlp = vec![0.0f32; rows * f];

        for (l, blk) in self.blocks.iter().enumerate() {
            h.copy_from_slice(&x);
            rmsnorm_rows(&mut h, d, &blk.norm1.data, cfg.norm_eps);
            blk.linears["q_proj"].forward_into(&h, rows, &mut q);
            blk.linears["k_proj"].forward_into(&h, rows, &mut kb);
            blk.linears["v_proj"].forward_into(&h, rows, &mut vb);
            // RoPE at the row-local position (== slot index during
            // prefill, since real tokens are left-aligned)
            for r in 0..b {
                for pos in 0..tmax {
                    for hi in 0..nh {
                        let o = (r * tmax + pos) * d + hi * hd;
                        rope_row(&mut q[o..o + hd], pos, cfg.rope_theta);
                    }
                    for hi in 0..nkv {
                        let o = (r * tmax + pos) * dkv + hi * hd;
                        rope_row(&mut kb[o..o + hd], pos, cfg.rope_theta);
                    }
                }
            }
            // cache layout is [t][b][d_kv]; the forward buffers are
            // [b][t][d_kv] — transposed copy
            for pos in 0..tmax {
                for r in 0..b {
                    let dst = (pos * b + r) * dkv;
                    let src = (r * tmax + pos) * dkv;
                    cache.k[l][dst..dst + dkv].copy_from_slice(&kb[src..src + dkv]);
                    cache.v[l][dst..dst + dkv].copy_from_slice(&vb[src..src + dkv]);
                }
            }
            // causal attention, parallel over (row, head) pairs; padded
            // query slots are skipped
            let ctx_ptr = ctx.as_ptr() as usize;
            let qd: &[f32] = &q;
            let kd: &[f32] = &kb;
            let vd: &[f32] = &vb;
            parallel_chunks(b * nh, |_, s0, e0| {
                let mut scores = vec![0.0f32; tmax];
                for bh in s0..e0 {
                    let r = bh / nh;
                    let hi = bh % nh;
                    let kvh = hi / rep;
                    for qt in 0..plens[r] {
                        let qrow = &qd[(r * tmax + qt) * d + hi * hd..][..hd];
                        let mut maxv = f32::NEG_INFINITY;
                        for (kt, s) in scores[..=qt].iter_mut().enumerate() {
                            let base = (r * tmax + kt) * dkv + kvh * hd;
                            let dot: f32 = qrow
                                .iter()
                                .zip(&kd[base..base + hd])
                                .map(|(a, c)| a * c)
                                .sum::<f32>()
                                * scale;
                            *s = dot;
                            maxv = maxv.max(dot);
                        }
                        let mut denom = 0.0f32;
                        for s in scores[..=qt].iter_mut() {
                            *s = (*s - maxv).exp();
                            denom += *s;
                        }
                        let out = unsafe {
                            std::slice::from_raw_parts_mut(
                                (ctx_ptr as *mut f32).add((r * tmax + qt) * d + hi * hd),
                                hd,
                            )
                        };
                        out.fill(0.0);
                        for (kt, s) in scores[..=qt].iter().enumerate() {
                            let w = s / denom;
                            if w == 0.0 {
                                continue;
                            }
                            let base = (r * tmax + kt) * dkv + kvh * hd;
                            for (o, &vv) in out.iter_mut().zip(&vd[base..base + hd]) {
                                *o += w * vv;
                            }
                        }
                    }
                }
            });
            blk.linears["o_proj"].forward_into(&ctx, rows, &mut proj);
            for (a, o) in x.iter_mut().zip(&proj) {
                *a += o;
            }

            h.copy_from_slice(&x);
            rmsnorm_rows(&mut h, d, &blk.norm2.data, cfg.norm_eps);
            blk.linears["gate_proj"].forward_into(&h, rows, &mut gate);
            blk.linears["up_proj"].forward_into(&h, rows, &mut up);
            for i in 0..rows * f {
                mlp[i] = silu(gate[i]) * up[i];
            }
            blk.linears["down_proj"].forward_into(&mlp, rows, &mut proj);
            for (a, o) in x.iter_mut().zip(&proj) {
                *a += o;
            }
        }

        cache.len = tmax;
        for pos in 0..tmax {
            for r in 0..b {
                cache.valid[pos * b + r] = pos < plens[r];
            }
        }
        for r in 0..b {
            cache.row_pos[r] = plens[r];
        }

        // head logits at each row's final prompt slot only
        let mut hl = vec![0.0f32; b * d];
        for r in 0..b {
            let src = (r * tmax + plens[r] - 1) * d;
            hl[r * d..(r + 1) * d].copy_from_slice(&x[src..src + d]);
        }
        rmsnorm_rows(&mut hl, d, &self.norm_f.data, cfg.norm_eps);
        let v = cfg.vocab_size;
        let mut logits = vec![0.0f32; b * v];
        linalg::matmul_bt_into(&hl, b, d, &self.emb.data, v, &mut logits);
        Ok(StepOut::from_logits(&mut logits, b, v, None))
    }

    /// Batched greedy generation (batched prefill); returns outputs +
    /// throughput stats. Ragged prompt lengths are fully supported: each
    /// row's output is exactly what that prompt yields when served solo.
    pub fn generate(
        &self,
        prompts: &[Vec<i32>],
        max_new: usize,
    ) -> Result<(Vec<Vec<i32>>, DecodeStats)> {
        self.generate_with(prompts, max_new, PrefillMode::Batched)
    }

    /// `generate` with an explicit prefill strategy (the per-token path is
    /// kept as the benchmark baseline for the batched one).
    pub fn generate_with(
        &self,
        prompts: &[Vec<i32>],
        max_new: usize,
        mode: PrefillMode,
    ) -> Result<(Vec<Vec<i32>>, DecodeStats)> {
        let b = prompts.len();
        if b == 0 {
            bail!("generate: empty prompt batch");
        }
        for (r, p) in prompts.iter().enumerate() {
            if p.is_empty() {
                bail!("generate: prompt {r} is empty");
            }
            if let Some(&t) = p.iter().find(|&&t| t < 0 || t as usize >= self.cfg.vocab_size)
            {
                bail!(
                    "generate: prompt {r} token {t} out of range (vocab {})",
                    self.cfg.vocab_size
                );
            }
        }
        let plens: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
        let tmax = plens.iter().copied().max().unwrap_or(0);
        let mut cache = KvCache::with_capacity(&self.cfg, b, tmax + max_new);
        let mut scratch = DecodeScratch::new(&self.cfg, b);
        let _sp = crate::span!("serve.generate", &self.label);

        let t0 = std::time::Instant::now();
        let pre = match mode {
            PrefillMode::Batched => self.prefill_batched(prompts, &plens, &mut cache)?,
            PrefillMode::PerToken => {
                self.prefill_per_token(prompts, &plens, &mut cache, &mut scratch)?
            }
        };
        if let Some(r) = pre.poisoned.iter().position(|&p| p) {
            return Err(ServeError::PoisonedLogits { row: r, step: plens[r] }.into());
        }
        let mut last = pre.toks;
        let prefill_s = t0.elapsed().as_secs_f64();

        let t1 = std::time::Instant::now();
        let all_valid = vec![true; b];
        let mut outs: Vec<Vec<i32>> = vec![Vec::with_capacity(max_new); b];
        for gen in 0..max_new {
            let ts = std::time::Instant::now();
            let step = self.decode_step(&last, &all_valid, &mut cache, &mut scratch, None)?;
            if let Some(r) = step.poisoned.iter().position(|&p| p) {
                // batch API has no per-row error channel; fail typed with
                // the offending row (the gateway fails rows individually)
                return Err(ServeError::PoisonedLogits { row: r, step: plens[r] + gen + 1 }
                    .into());
            }
            last = step.toks;
            // per-request latency histogram for the packed qmatmul path
            crate::obs::hist_record(
                "serve.decode_step_us",
                ts.elapsed().as_secs_f64() * 1e6,
            );
            for (r, &tok) in last.iter().enumerate() {
                outs[r].push(tok);
            }
        }
        let decode_s = t1.elapsed().as_secs_f64();
        let prompt_tokens: usize = plens.iter().sum();
        let stats = DecodeStats {
            label: self.label.clone(),
            batch: b,
            prompt_len: tmax,
            prompt_lens: plens,
            new_tokens: max_new,
            prefill_s,
            decode_s,
            tokens_per_s: (b * max_new) as f64 / decode_s,
            prefill_tokens_per_s: prompt_tokens as f64 / prefill_s,
            weight_bytes: self.weight_bytes(),
        };
        if crate::obs::enabled() {
            let plens_s = stats
                .prompt_lens
                .iter()
                .map(|l| l.to_string())
                .collect::<Vec<_>>()
                .join(",");
            crate::obs::event(
                "serve_request",
                &[
                    ("label", stats.label.as_str().into()),
                    ("batch", stats.batch.into()),
                    ("prompt_len", stats.prompt_len.into()),
                    ("prompt_lens", plens_s.into()),
                    ("new_tokens", stats.new_tokens.into()),
                    ("prefill_s", stats.prefill_s.into()),
                    ("decode_s", stats.decode_s.into()),
                    ("tokens_per_s", stats.tokens_per_s.into()),
                    ("prefill_tokens_per_s", stats.prefill_tokens_per_s.into()),
                    ("weight_bytes", stats.weight_bytes.into()),
                ],
            );
        }
        Ok((outs, stats))
    }
}

fn rope_row(row: &mut [f32], pos: usize, theta: f32) {
    let hd = row.len();
    let half = hd / 2;
    for i in 0..half {
        let inv = 1.0 / theta.powf((2 * i) as f32 / hd as f32);
        let ang = pos as f32 * inv;
        let (s, c) = ang.sin_cos();
        let a = row[i];
        let b2 = row[i + half];
        row[i] = a * c - b2 * s;
        row[i + half] = a * s + b2 * c;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg32;

    #[test]
    fn dense_generation_is_deterministic() {
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(0);
        let p = Params::init(&cfg, &mut rng);
        let m = ServeModel::dense(&p);
        let prompts = vec![vec![1, 2, 3], vec![4, 5, 6]];
        let (o1, s1) = m.generate(&prompts, 8).unwrap();
        let (o2, _) = m.generate(&prompts, 8).unwrap();
        assert_eq!(o1, o2);
        assert_eq!(o1[0].len(), 8);
        assert!(s1.tokens_per_s > 0.0);
        assert!(s1.prefill_s > 0.0);
        assert_eq!(s1.prompt_lens, vec![3, 3]);
        assert!(o1.iter().flatten().all(|&t| (t as usize) < cfg.vocab_size));
    }

    #[test]
    fn decode_matches_prefill_forward() {
        // Greedy next token from incremental decode must equal the argmax
        // from the host full forward at the same position.
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(1);
        let p = Params::init(&cfg, &mut rng);
        let m = ServeModel::dense(&p);
        let prompt = vec![3i32, 17, 40, 9];

        // incremental
        let mut cache = KvCache::new(&cfg, 1);
        let mut scratch = DecodeScratch::new(&cfg, 1);
        let mut next = 0;
        for pos in 0..prompt.len() {
            next = m
                .decode_step(&prompt[pos..pos + 1], &[true], &mut cache, &mut scratch, None)
                .unwrap()
                .toks[0];
        }

        // full forward on host
        use crate::model::hostfwd::{block_fwd, BlockFwdOpts};
        let x0 = p.embed(&prompt, 1, prompt.len());
        let mut h = x0;
        for l in 0..cfg.n_layers {
            h = block_fwd(&h, &p.block(l), &cfg, &BlockFwdOpts::default()).0;
        }
        let d = cfg.d_model;
        let tlast = prompt.len() - 1;
        let mut hrow = h.data[tlast * d..(tlast + 1) * d].to_vec();
        rmsnorm_rows(&mut hrow, d, &p.get("norm_f").data, cfg.norm_eps);
        let hrow = Tensor::new(vec![1, d], hrow);
        let logits = linalg::matmul_bt(&hrow, p.get("emb"));
        let want = logits
            .data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as i32;
        assert_eq!(next, want, "incremental decode diverged from prefill");
    }

    #[test]
    fn batched_prefill_matches_per_token() {
        // The fast multi-token prefill must produce the exact same
        // generation as the token-by-token baseline, ragged or not.
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(3);
        let p = Params::init(&cfg, &mut rng);
        let m = ServeModel::dense(&p);
        for prompts in [
            vec![vec![1i32, 2, 3, 4], vec![5, 6, 7, 8]],
            vec![vec![9i32, 8, 7, 6, 5, 4], vec![1, 2], vec![3, 3, 3]],
        ] {
            let (ob, sb) = m.generate_with(&prompts, 6, PrefillMode::Batched).unwrap();
            let (ot, _) = m.generate_with(&prompts, 6, PrefillMode::PerToken).unwrap();
            assert_eq!(ob, ot, "prefill modes diverged for {prompts:?}");
            assert_eq!(sb.prompt_lens, prompts.iter().map(|p| p.len()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn ragged_batch_matches_solo() {
        // THE regression for the KV-cache pollution bug: a short prompt
        // batched with a longer one must generate exactly the tokens it
        // generates alone. The old code re-fed the short prompt's last
        // token during padded prefill steps, so its output depended on its
        // batchmates.
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(4);
        let p = Params::init(&cfg, &mut rng);
        let m = ServeModel::dense(&p);
        let long = vec![3i32, 17, 40, 9, 22, 5, 61, 30];
        let short = vec![12i32, 7, 44];
        let (solo_long, _) = m.generate(std::slice::from_ref(&long), 8).unwrap();
        let (solo_short, _) = m.generate(std::slice::from_ref(&short), 8).unwrap();
        for mode in [PrefillMode::Batched, PrefillMode::PerToken] {
            let (batched, stats) =
                m.generate_with(&[long.clone(), short.clone()], 8, mode).unwrap();
            assert_eq!(batched[0], solo_long[0], "{mode:?}: long row polluted");
            assert_eq!(batched[1], solo_short[0], "{mode:?}: short row polluted");
            assert_eq!(stats.prompt_lens, vec![8, 3]);
        }
    }

    #[test]
    fn kv_cache_grows_past_capacity() {
        // with_capacity is a fast path, not a hard limit: generating past
        // the preallocated slots must transparently grow the cache.
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(5);
        let p = Params::init(&cfg, &mut rng);
        let m = ServeModel::dense(&p);
        let prompt = vec![vec![1i32, 2, 3]];
        let mut cache = KvCache::with_capacity(&cfg, 1, 2);
        let mut scratch = DecodeScratch::new(&cfg, 1);
        let mut tok = 1i32;
        for pos in 0..6 {
            let t = if pos < 3 { prompt[0][pos] } else { tok };
            tok = m.decode_step(&[t], &[true], &mut cache, &mut scratch, None).unwrap().toks[0];
        }
        assert_eq!(cache.len, 6);
        assert!(cache.capacity() >= 6);
        assert_eq!(cache.row_pos(0), 6);
        // and the grown-cache decode matches a roomy cache from scratch
        let (full, _) = m.generate(&prompt, 3).unwrap();
        let mut cache2 = KvCache::with_capacity(&cfg, 1, 64);
        let mut scratch2 = DecodeScratch::new(&cfg, 1);
        let mut tok2 = 0i32;
        for pos in 0..3 {
            tok2 = m
                .decode_step(&[prompt[0][pos]], &[true], &mut cache2, &mut scratch2, None)
                .unwrap()
                .toks[0];
        }
        let mut got = vec![tok2];
        for _ in 0..2 {
            tok2 = m.decode_step(&[tok2], &[true], &mut cache2, &mut scratch2, None)
                .unwrap()
                .toks[0];
            got.push(tok2);
        }
        assert_eq!(got, full[0]);
    }

    #[test]
    fn argmax_checked_flags_non_finite() {
        assert_eq!(argmax_checked(&[1.0, 3.0, 2.0]), Some(1));
        // ties keep the LAST maximal index (old max_by behavior)
        assert_eq!(argmax_checked(&[5.0, 5.0, 1.0]), Some(1));
        assert_eq!(argmax_checked(&[]), None);
        assert_eq!(argmax_checked(&[1.0, f32::NAN, 2.0]), None);
        assert_eq!(argmax_checked(&[f32::INFINITY, 0.0]), None);
        assert_eq!(argmax_checked(&[f32::NEG_INFINITY]), None);
    }

    #[test]
    fn poisoned_logits_fail_typed_not_token_zero() {
        // REGRESSION for the silent-NaN decode: a model whose logits go
        // non-finite must surface ServeError::PoisonedLogits, not emit
        // token 0 and keep going. NaN in the final-norm weights poisons
        // the head logits of every row from the very first step.
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(6);
        let mut p = Params::init(&cfg, &mut rng);
        p.get_mut("norm_f").data[0] = f32::NAN;
        let m = ServeModel::dense(&p);
        let err = m.generate(&[vec![1i32, 2, 3]], 4).unwrap_err();
        let se = err.downcast_ref::<ServeError>().expect("typed ServeError");
        assert!(matches!(se, ServeError::PoisonedLogits { row: 0, .. }), "{se:?}");
    }

    #[test]
    fn poison_mask_trips_row_sentinel() {
        // the fault-injection hook corrupts exactly the masked rows and
        // leaves the others decoding normally
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(7);
        let p = Params::init(&cfg, &mut rng);
        let m = ServeModel::dense(&p);
        let mut cache = KvCache::new(&cfg, 2);
        let mut scratch = DecodeScratch::new(&cfg, 2);
        let out = m
            .decode_step(&[1, 2], &[true, true], &mut cache, &mut scratch, Some(&[false, true]))
            .unwrap();
        assert!(!out.poisoned[0]);
        assert!(out.poisoned[1]);
        assert!((out.toks[0] as usize) < cfg.vocab_size);
    }

    #[test]
    fn kv_cache_capacity_cap_is_typed_error() {
        // growth at the boundary succeeds; one slot past max_slots is a
        // typed KvCapacity error, not an unbounded reallocation
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(8);
        let p = Params::init(&cfg, &mut rng);
        let m = ServeModel::dense(&p);
        let mut cache = KvCache::with_limits(&cfg, 1, 2, 4);
        assert_eq!(cache.max_slots(), 4);
        let mut scratch = DecodeScratch::new(&cfg, 1);
        let mut tok = 1i32;
        for _ in 0..4 {
            // grows 2 -> 4 at the boundary, never past the cap
            tok = m.decode_step(&[tok], &[true], &mut cache, &mut scratch, None).unwrap().toks
                [0];
            assert!(cache.capacity() <= 4);
        }
        assert_eq!(cache.len, 4);
        let err = m.decode_step(&[tok], &[true], &mut cache, &mut scratch, None).unwrap_err();
        assert_eq!(err, ServeError::KvCapacity { need: 5, max_slots: 4 });
        // cap stays intact after the refusal
        assert_eq!(cache.len, 4);
        assert_eq!(cache.capacity(), 4);
    }

    #[test]
    fn kv_cache_reset_row_isolates_new_occupant() {
        // a recycled row slot must not see its predecessor's KV: after
        // reset_row the newcomer's decode matches a solo run exactly
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(9);
        let p = Params::init(&cfg, &mut rng);
        let m = ServeModel::dense(&p);
        let first = vec![3i32, 17, 40];
        let second = vec![12i32, 7, 44, 9];

        let mut cache = KvCache::with_capacity(&cfg, 1, 32);
        let mut scratch = DecodeScratch::new(&cfg, 1);
        for &t in &first {
            m.decode_step(&[t], &[true], &mut cache, &mut scratch, None).unwrap();
        }
        cache.reset_row(0);
        assert_eq!(cache.row_pos(0), 0);
        let mut got = Vec::new();
        let mut tok = 0i32;
        for (i, &t) in second.iter().enumerate() {
            tok = m.decode_step(&[t], &[true], &mut cache, &mut scratch, None).unwrap().toks[0];
            if i + 1 == second.len() {
                got.push(tok);
            }
        }
        for _ in 0..3 {
            tok = m.decode_step(&[tok], &[true], &mut cache, &mut scratch, None).unwrap().toks
                [0];
            got.push(tok);
        }
        let (solo, _) = m.generate(std::slice::from_ref(&second), 4).unwrap();
        assert_eq!(got, solo[0], "recycled slot leaked its previous occupant's KV");
    }
}
