//! Quantized serving path (Table 8): batched greedy decoding with a KV
//! cache over packed INT{2,3,4} weights (Rust-native fused dequant
//! kernels, quant::pack) or dense f32 weights (the FP16-equivalent
//! baseline). Reports weight memory and tokens/second.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::coordinator::par::CalibReport;
use crate::model::hostfwd::{rmsnorm_rows, silu, LinearOp};
use crate::model::{ModelConfig, Params, LINEAR_NAMES};
use crate::quant::pack::PackedLinear;
use crate::tensor::{linalg, Tensor};

/// A servable model: embedding + per-block linear ops (dense or packed).
pub struct ServeModel {
    pub cfg: ModelConfig,
    pub emb: Tensor,
    pub norm_f: Tensor,
    pub blocks: Vec<ServeBlock>,
    pub label: String,
}

pub struct ServeBlock {
    pub linears: BTreeMap<String, Box<dyn LinearOp>>,
    pub norm1: Tensor,
    pub norm2: Tensor,
}

impl ServeModel {
    /// Dense (FP16-equivalent) serving model from parameters.
    pub fn dense(params: &Params) -> ServeModel {
        let cfg = params.cfg.clone();
        let blocks = (0..cfg.n_layers)
            .map(|l| {
                let bv = params.block(l);
                let linears: BTreeMap<String, Box<dyn LinearOp>> = bv
                    .linears
                    .iter()
                    .map(|(k, v)| (k.clone(), Box::new(v.clone()) as Box<dyn LinearOp>))
                    .collect();
                ServeBlock { linears, norm1: bv.norm1, norm2: bv.norm2 }
            })
            .collect();
        ServeModel {
            cfg: cfg.clone(),
            emb: params.get("emb").clone(),
            norm_f: params.get("norm_f").clone(),
            blocks,
            label: "FP16".into(),
        }
    }

    /// Packed model from a TesseraQ calibration report (codes + effective
    /// scales). Embedding and norms stay dense, like the paper. Fails with
    /// context if the report is missing blocks/linears (e.g. built from a
    /// partial calibration) or if codes overflow `bits`.
    pub fn packed(params: &Params, report: &CalibReport, bits: u32) -> Result<ServeModel> {
        let cfg = params.cfg.clone();
        if report.quantized.len() < cfg.n_layers {
            bail!(
                "calibration report covers {} blocks, model has {} — partial run?",
                report.quantized.len(),
                cfg.n_layers
            );
        }
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let bv = params.block(l);
            let mut linears: BTreeMap<String, Box<dyn LinearOp>> = BTreeMap::new();
            for name in LINEAR_NAMES {
                let (codes, qp) = report.quantized[l].get(name).with_context(|| {
                    format!("calibration report block {l} has no codes for {name:?}")
                })?;
                let (o, i) = cfg.linear_shape(name);
                let pl = PackedLinear::from_codes(codes, o, i, bits, qp.clone())
                    .with_context(|| format!("packing block {l} {name}"))?;
                linears.insert(name.to_string(), Box::new(pl) as Box<dyn LinearOp>);
            }
            blocks.push(ServeBlock { linears, norm1: bv.norm1, norm2: bv.norm2 });
        }
        Ok(ServeModel {
            cfg: cfg.clone(),
            emb: params.get("emb").clone(),
            norm_f: params.get("norm_f").clone(),
            blocks,
            label: format!("W{bits} packed"),
        })
    }

    /// Weight memory in bytes (Table 8 "WM" column; FP16 reference for
    /// dense tensors).
    pub fn weight_bytes(&self) -> usize {
        let mut n = self.emb.data.len() * 2 + self.norm_f.data.len() * 2;
        for b in &self.blocks {
            n += (b.norm1.data.len() + b.norm2.data.len()) * 2;
            for lin in b.linears.values() {
                n += lin.weight_bytes();
            }
        }
        n
    }
}

/// KV cache for one decode session: [layer][b, t, d_kv] grown per step.
pub struct KvCache {
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    pub len: usize,
    b: usize,
    d_kv: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig, b: usize) -> KvCache {
        KvCache {
            k: vec![Vec::new(); cfg.n_layers],
            v: vec![Vec::new(); cfg.n_layers],
            len: 0,
            b,
            d_kv: cfg.d_kv(),
        }
    }
}

pub struct DecodeStats {
    pub label: String,
    pub batch: usize,
    pub prompt_len: usize,
    pub new_tokens: usize,
    pub tokens_per_s: f64,
    pub weight_bytes: usize,
}

impl ServeModel {
    /// One decode step for batch `b`: last-token activations [b, d] ->
    /// next-token ids [b]. Appends to the cache.
    fn decode_step(&self, x_tok: &[i32], cache: &mut KvCache) -> Vec<i32> {
        let cfg = &self.cfg;
        let b = cache.b;
        let d = cfg.d_model;
        let pos = cache.len;
        // embed
        let mut x = vec![0.0f32; b * d];
        for (r, &tok) in x_tok.iter().enumerate() {
            x[r * d..(r + 1) * d]
                .copy_from_slice(&self.emb.data[tok as usize * d..(tok as usize + 1) * d]);
        }

        let nh = cfg.n_heads;
        let nkv = cfg.n_kv_heads;
        let hd = cfg.head_dim();
        let rep = nh / nkv;
        let scale = 1.0 / (hd as f32).sqrt();

        for (l, blk) in self.blocks.iter().enumerate() {
            let mut h = Tensor::new(vec![b, d], x.clone());
            rmsnorm_rows(&mut h.data, d, &blk.norm1.data, cfg.norm_eps);
            let q = blk.linears["q_proj"].forward(&h);
            let mut k = blk.linears["k_proj"].forward(&h);
            let v = blk.linears["v_proj"].forward(&h);
            // rope on q (per head) and k (per kv head) at `pos`
            let mut qd = q.data;
            for r in 0..b {
                for hi in 0..nh {
                    rope_row(&mut qd[r * d + hi * hd..r * d + (hi + 1) * hd], pos, cfg.rope_theta);
                }
                for hi in 0..nkv {
                    rope_row(
                        &mut k.data[r * cfg.d_kv() + hi * hd..r * cfg.d_kv() + (hi + 1) * hd],
                        pos,
                        cfg.rope_theta,
                    );
                }
            }
            cache.k[l].extend_from_slice(&k.data);
            cache.v[l].extend_from_slice(&v.data);

            // attention over the cache (t = pos + 1 entries)
            let t = pos + 1;
            let dkv = cache.d_kv;
            let mut ctx = vec![0.0f32; b * d];
            for r in 0..b {
                for hi in 0..nh {
                    let kvh = hi / rep;
                    let qrow = &qd[r * d + hi * hd..r * d + (hi + 1) * hd];
                    let mut scores = vec![0.0f32; t];
                    let mut maxv = f32::NEG_INFINITY;
                    for kt in 0..t {
                        let base = (kt * b + r) * dkv + kvh * hd;
                        let krow = &cache.k[l][base..base + hd];
                        let dot: f32 =
                            qrow.iter().zip(krow).map(|(a, c)| a * c).sum::<f32>() * scale;
                        scores[kt] = dot;
                        maxv = maxv.max(dot);
                    }
                    let mut denom = 0.0f32;
                    for s in scores.iter_mut() {
                        *s = (*s - maxv).exp();
                        denom += *s;
                    }
                    let out = &mut ctx[r * d + hi * hd..r * d + (hi + 1) * hd];
                    for kt in 0..t {
                        let w = scores[kt] / denom;
                        let base = (kt * b + r) * dkv + kvh * hd;
                        for (o, &vv) in out.iter_mut().zip(&cache.v[l][base..base + hd]) {
                            *o += w * vv;
                        }
                    }
                }
            }
            let attn = blk.linears["o_proj"].forward(&Tensor::new(vec![b, d], ctx));
            for (a, o) in x.iter_mut().zip(&attn.data) {
                *a += o;
            }

            let mut h2 = Tensor::new(vec![b, d], x.clone());
            rmsnorm_rows(&mut h2.data, d, &blk.norm2.data, cfg.norm_eps);
            let gate = blk.linears["gate_proj"].forward(&h2);
            let up = blk.linears["up_proj"].forward(&h2);
            let f = cfg.d_ff;
            let mut mlp = vec![0.0f32; b * f];
            for i in 0..b * f {
                mlp[i] = silu(gate.data[i]) * up.data[i];
            }
            let down = blk.linears["down_proj"].forward(&Tensor::new(vec![b, f], mlp));
            for (a, o) in x.iter_mut().zip(&down.data) {
                *a += o;
            }
        }
        cache.len += 1;

        // head: greedy over tied embedding
        let mut hf = Tensor::new(vec![b, d], x);
        rmsnorm_rows(&mut hf.data, d, &self.norm_f.data, cfg.norm_eps);
        let logits = linalg::matmul_bt(&hf, &self.emb);
        let v = cfg.vocab_size;
        (0..b)
            .map(|r| {
                let row = &logits.data[r * v..(r + 1) * v];
                // total_cmp: NaN logits (e.g. a degenerate quantized model)
                // must not panic the decode loop
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect()
    }

    /// Batched greedy generation; returns outputs + throughput stats.
    pub fn generate(
        &self,
        prompts: &[Vec<i32>],
        max_new: usize,
    ) -> Result<(Vec<Vec<i32>>, DecodeStats)> {
        let b = prompts.len();
        if b == 0 {
            bail!("generate: empty prompt batch");
        }
        for (r, p) in prompts.iter().enumerate() {
            if p.is_empty() {
                bail!("generate: prompt {r} is empty");
            }
            if let Some(&t) = p.iter().find(|&&t| t < 0 || t as usize >= self.cfg.vocab_size)
            {
                bail!(
                    "generate: prompt {r} token {t} out of range (vocab {})",
                    self.cfg.vocab_size
                );
            }
        }
        let plen = prompts.iter().map(|p| p.len()).max().unwrap_or(0);
        let mut cache = KvCache::new(&self.cfg, b);
        // prefill token-by-token (decode-path benchmark, like TP_n in the
        // paper measures generated tokens/s)
        let mut last: Vec<i32> = vec![0; b];
        for pos in 0..plen {
            let toks: Vec<i32> =
                prompts.iter().map(|p| p[pos.min(p.len() - 1)]).collect();
            last = self.decode_step(&toks, &mut cache);
        }
        let _sp = crate::span!("serve.generate", &self.label);
        let t0 = std::time::Instant::now();
        let mut outs: Vec<Vec<i32>> = vec![Vec::with_capacity(max_new); b];
        for _ in 0..max_new {
            let ts = std::time::Instant::now();
            last = self.decode_step(&last, &mut cache);
            // per-request latency histogram for the packed qmatmul path
            crate::obs::hist_record(
                "serve.decode_step_us",
                ts.elapsed().as_secs_f64() * 1e6,
            );
            for (r, &tok) in last.iter().enumerate() {
                outs[r].push(tok);
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        let stats = DecodeStats {
            label: self.label.clone(),
            batch: b,
            prompt_len: plen,
            new_tokens: max_new,
            tokens_per_s: (b * max_new) as f64 / dt,
            weight_bytes: self.weight_bytes(),
        };
        if crate::obs::enabled() {
            crate::obs::event(
                "serve_request",
                &[
                    ("label", stats.label.as_str().into()),
                    ("batch", stats.batch.into()),
                    ("prompt_len", stats.prompt_len.into()),
                    ("new_tokens", stats.new_tokens.into()),
                    ("tokens_per_s", stats.tokens_per_s.into()),
                    ("weight_bytes", stats.weight_bytes.into()),
                ],
            );
        }
        Ok((outs, stats))
    }
}

fn rope_row(row: &mut [f32], pos: usize, theta: f32) {
    let hd = row.len();
    let half = hd / 2;
    for i in 0..half {
        let inv = 1.0 / theta.powf((2 * i) as f32 / hd as f32);
        let ang = pos as f32 * inv;
        let (s, c) = ang.sin_cos();
        let a = row[i];
        let b2 = row[i + half];
        row[i] = a * c - b2 * s;
        row[i + half] = a * s + b2 * c;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg32;

    #[test]
    fn dense_generation_is_deterministic() {
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(0);
        let p = Params::init(&cfg, &mut rng);
        let m = ServeModel::dense(&p);
        let prompts = vec![vec![1, 2, 3], vec![4, 5, 6]];
        let (o1, s1) = m.generate(&prompts, 8).unwrap();
        let (o2, _) = m.generate(&prompts, 8).unwrap();
        assert_eq!(o1, o2);
        assert_eq!(o1[0].len(), 8);
        assert!(s1.tokens_per_s > 0.0);
        assert!(o1.iter().flatten().all(|&t| (t as usize) < cfg.vocab_size));
    }

    #[test]
    fn decode_matches_prefill_forward() {
        // Greedy next token from incremental decode must equal the argmax
        // from the host full forward at the same position.
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(1);
        let p = Params::init(&cfg, &mut rng);
        let m = ServeModel::dense(&p);
        let prompt = vec![3i32, 17, 40, 9];

        // incremental
        let mut cache = KvCache::new(&cfg, 1);
        let mut next = 0;
        for pos in 0..prompt.len() {
            next = m.decode_step(&prompt[pos..pos + 1].to_vec(), &mut cache)[0];
        }

        // full forward on host
        use crate::model::hostfwd::{block_fwd, BlockFwdOpts};
        let x0 = p.embed(&prompt, 1, prompt.len());
        let mut h = x0;
        for l in 0..cfg.n_layers {
            h = block_fwd(&h, &p.block(l), &cfg, &BlockFwdOpts::default()).0;
        }
        let d = cfg.d_model;
        let tlast = prompt.len() - 1;
        let mut hrow = h.data[tlast * d..(tlast + 1) * d].to_vec();
        rmsnorm_rows(&mut hrow, d, &p.get("norm_f").data, cfg.norm_eps);
        let hrow = Tensor::new(vec![1, d], hrow);
        let logits = linalg::matmul_bt(&hrow, p.get("emb"));
        let want = logits
            .data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as i32;
        assert_eq!(next, want, "incremental decode diverged from prefill");
    }
}
