//! Overload-safe serving gateway: continuous batching with deadlines,
//! admission control, and graceful degradation in front of [`ServeModel`].
//!
//! The gateway turns the batch-generate API into a trafficable serving
//! system:
//!
//! * **Bounded admission queue** — submissions past `queue_depth` are
//!   shed synchronously with a typed [`ShedReason`]; a request whose
//!   `prompt_len + max_new` can never fit the per-session KV budget is
//!   refused up front instead of OOMing mid-flight.
//! * **Continuous batching** — one serving session holds `max_batch`
//!   row slots over a shared KV time axis. Rows join and leave at
//!   decode-step boundaries: a completed/evicted row's slot is recycled
//!   for the next queued request ([`KvCache::reset_row`] clears the
//!   newcomer's validity column, so it can never attend a predecessor's
//!   KV). Because every row attends only its own valid slots and runs
//!   RoPE at its own `row_pos`, each request's output is bit-identical
//!   to its solo run regardless of who else shares the batch — the same
//!   masking contract that makes ragged batches exact.
//! * **Deadlines at decode-step granularity** — before every step,
//!   queued requests past their deadline are failed without running and
//!   in-flight rows past theirs are evicted mid-batch; survivors are
//!   untouched (the evicted row simply stops being fed).
//! * **Graceful degradation** — NaN/Inf logits fail *that row* with a
//!   typed [`ServeError::PoisonedLogits`] (never a silent token 0). On
//!   the packed path the failed request is retried on the dense
//!   fallback via `robust::with_retry`, and repeated packed failures
//!   trip a circuit breaker that moves all subsequent sessions to the
//!   dense model.
//! * **Chaos hooks** — `TESSERAQ_FAULTS` request-level kinds
//!   (`slow@step.ms`, `poison@req.step`, `stall@iter.ms`, `kill@step`)
//!   drive deterministic drills: injected delays advance the gateway's
//!   synthetic clock, so deadline behavior cannot flip on scheduler
//!   jitter.
//!
//! Telemetry: `gateway_admit` / `gateway_shed` / `gateway_deadline_miss`
//! / `gateway_degrade` / `gateway_session_abort` events plus
//! `gateway.queue_depth`, `gateway.time_in_queue_ms`,
//! `gateway.request_latency_ms`, and `gateway.decode_step_us`
//! histograms through `obs::`.

use std::collections::{BTreeMap, VecDeque};
use std::rc::Rc;

use crate::robust::{with_retry, FaultPlan};

use super::sched::{
    DeadlineStage, GatewayClock, GatewayConfig, GatewayCounters, KvLedger, Request,
    RequestOutcome, ServeError, ShedReason,
};
use super::sched::Breaker;
use super::{DecodeScratch, KvCache, ServeModel};

/// An admitted request waiting for (or returned to) the queue.
struct Admitted {
    id: u64,
    prompt: Vec<i32>,
    max_new: usize,
    /// Resolved deadline (request's own or the gateway default).
    deadline_ms: Option<u64>,
    submit_ms: u64,
    /// Already survived one session abort; a second abort fails it.
    requeued: bool,
}

/// One in-flight row of the active session.
struct RowState {
    id: u64,
    prompt: Vec<i32>,
    max_new: usize,
    deadline_ms: Option<u64>,
    submit_ms: u64,
    /// Tokens fed so far — the request's own 1-based step counter is
    /// `fed + 1` (prefill steps included); fault sites key on it.
    fed: usize,
    /// Prompt tokens fed so far (< prompt.len() means still prefilling).
    pos: usize,
    out: Vec<i32>,
    last: i32,
    requeued: bool,
}

impl RowState {
    fn expired(&self, now_ms: u64) -> bool {
        match self.deadline_ms {
            Some(d) => now_ms.saturating_sub(self.submit_ms) > d,
            None => false,
        }
    }
}

/// The active serving session: a KV time axis shared by up to
/// `max_batch` row slots.
struct Session {
    cache: KvCache,
    scratch: DecodeScratch,
    rows: Vec<Option<RowState>>,
    /// Running on the dense fallback (breaker tripped).
    dense: bool,
}

impl Session {
    fn active(&self) -> usize {
        self.rows.iter().filter(|r| r.is_some()).count()
    }
}

/// Request-level serving gateway over a [`ServeModel`]. Single-threaded
/// by design — the decode step itself parallelizes over (row, head) —
/// with an explicit `step()` pump so load generators and chaos drills
/// control interleaving deterministically.
pub struct Gateway<'m> {
    primary: &'m ServeModel,
    fallback: Option<&'m ServeModel>,
    cfg: GatewayConfig,
    faults: Option<Rc<FaultPlan>>,
    clock: GatewayClock,
    queue: VecDeque<Admitted>,
    session: Option<Session>,
    outcomes: BTreeMap<u64, RequestOutcome>,
    ledger: KvLedger,
    breaker: Breaker,
    counters: GatewayCounters,
    next_id: u64,
    /// Global decode-step counter (1-based; `kill@N` / `slow@N` sites).
    step_no: usize,
    /// Pump-iteration counter (1-based; `stall@N` sites).
    pump_no: usize,
    degraded: bool,
}

impl<'m> Gateway<'m> {
    pub fn new(primary: &'m ServeModel, cfg: GatewayConfig) -> Gateway<'m> {
        let breaker = Breaker::new(cfg.breaker_threshold);
        Gateway {
            primary,
            fallback: None,
            cfg,
            faults: None,
            clock: GatewayClock::default(),
            queue: VecDeque::new(),
            session: None,
            outcomes: BTreeMap::new(),
            ledger: KvLedger::default(),
            breaker,
            counters: GatewayCounters::default(),
            next_id: 0,
            step_no: 0,
            pump_no: 0,
            degraded: false,
        }
    }

    /// Dense fallback model for the degradation ladder. Must share the
    /// primary's `ModelConfig` (same vocab/shape); typically
    /// `ServeModel::dense` of the same parameters.
    pub fn with_fallback(mut self, fallback: &'m ServeModel) -> Gateway<'m> {
        debug_assert_eq!(fallback.cfg, self.primary.cfg, "fallback config mismatch");
        self.fallback = Some(fallback);
        self
    }

    /// Arm deterministic fault injection (chaos drills).
    pub fn with_faults(mut self, plan: Rc<FaultPlan>) -> Gateway<'m> {
        self.faults = Some(plan);
        self
    }

    /// Current gateway time (wall + synthetic fault time), ms.
    pub fn now_ms(&self) -> u64 {
        self.clock.now_ms()
    }

    /// Advance synthetic time (open-loop load generators skipping to
    /// the next arrival).
    pub fn advance_ms(&mut self, ms: u64) {
        self.clock.advance_ms(ms);
    }

    pub fn counters(&self) -> &GatewayCounters {
        &self.counters
    }

    /// Terminal outcomes of admitted requests, keyed by request id.
    pub fn outcomes(&self) -> &BTreeMap<u64, RequestOutcome> {
        &self.outcomes
    }

    pub fn take_outcomes(&mut self) -> BTreeMap<u64, RequestOutcome> {
        std::mem::take(&mut self.outcomes)
    }

    /// KV slot-units currently reserved by in-flight requests; must be
    /// zero after a full drain (the "no leaked slots" invariant).
    pub fn kv_in_use(&self) -> usize {
        self.ledger.in_use()
    }

    pub fn kv_peak(&self) -> usize {
        self.ledger.peak()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Has the circuit breaker moved the gateway to the dense path?
    pub fn is_degraded(&self) -> bool {
        self.degraded
    }

    /// No queued work and no in-flight rows.
    pub fn idle(&self) -> bool {
        self.queue.is_empty()
            && self.session.as_ref().map(|s| s.active() == 0).unwrap_or(true)
    }

    /// Admission control: validate, check the KV budget, and enqueue —
    /// or shed with a typed reason. O(prompt) and synchronous; never
    /// blocks on in-flight work.
    pub fn submit(&mut self, req: Request) -> Result<u64, ShedReason> {
        self.counters.submitted += 1;
        let id = self.next_id;
        self.next_id += 1;
        let shed = |reason: ShedReason, gw: &mut Self| {
            gw.counters.shed += 1;
            crate::obs::event(
                "gateway_shed",
                &[
                    ("id", id.into()),
                    ("reason", reason.tag().into()),
                    ("detail", format!("{reason}").into()),
                    ("queue_depth", gw.queue.len().into()),
                ],
            );
            Err(reason)
        };
        if req.prompt.is_empty() {
            return shed(ShedReason::InvalidPrompt("empty prompt".into()), self);
        }
        if req.max_new == 0 {
            return shed(ShedReason::InvalidPrompt("max_new == 0".into()), self);
        }
        let vocab = self.primary.cfg.vocab_size;
        if let Some(&t) = req.prompt.iter().find(|&&t| t < 0 || t as usize >= vocab) {
            return shed(
                ShedReason::InvalidPrompt(format!("token {t} outside vocab {vocab}")),
                self,
            );
        }
        let need = req.kv_slots();
        if need > self.cfg.kv_slot_budget {
            return shed(ShedReason::KvBudget { need, budget: self.cfg.kv_slot_budget }, self);
        }
        if self.queue.len() >= self.cfg.queue_depth {
            return shed(ShedReason::QueueFull { depth: self.cfg.queue_depth }, self);
        }
        let now = self.clock.now_ms();
        let deadline_ms = req.deadline_ms.or(self.cfg.default_deadline_ms);
        self.queue.push_back(Admitted {
            id,
            prompt: req.prompt,
            max_new: req.max_new,
            deadline_ms,
            submit_ms: now,
            requeued: false,
        });
        self.counters.admitted += 1;
        crate::obs::hist_record("gateway.queue_depth", self.queue.len() as f64);
        crate::obs::event(
            "gateway_admit",
            &[
                ("id", id.into()),
                ("prompt_len", self.queue.back().map(|a| a.prompt.len()).unwrap_or(0).into()),
                ("max_new", self.queue.back().map(|a| a.max_new).unwrap_or(0).into()),
                ("deadline_ms", deadline_ms.unwrap_or(0).into()),
                ("queue_depth", self.queue.len().into()),
            ],
        );
        Ok(id)
    }

    /// Run the gateway until every admitted request has a terminal
    /// outcome.
    pub fn drain(&mut self) {
        while self.step() {}
    }

    /// One pump iteration: expire queued deadlines, fill free row
    /// slots, evict expired rows, then run one decode step over the
    /// active session. Returns false once idle.
    pub fn step(&mut self) -> bool {
        if self.idle() {
            self.session = None;
            return false;
        }
        self.pump_no += 1;
        if let Some(ms) = self.faults.as_ref().and_then(|p| p.queue_stall(self.pump_no)) {
            self.clock.advance_ms(ms);
        }
        self.expire_queue();
        let mut sess = match self.session.take() {
            // breaker tripped between requests: retire an idle packed
            // session so the next cohort runs on the dense fallback
            // (in-flight packed rows are never yanked — they finish, and
            // any that poison fall back individually)
            Some(s) if self.degraded && !s.dense && s.active() == 0 => {
                if self.queue.is_empty() {
                    return false;
                }
                self.new_session()
            }
            Some(s) => s,
            None => {
                if self.queue.is_empty() {
                    return false;
                }
                self.new_session()
            }
        };
        self.fill_rows(&mut sess);
        self.evict_expired(&mut sess);
        if sess.active() == 0 {
            // nothing runnable on this time axis: drop the session so the
            // head of the queue gets a fresh one next pump (admission
            // guarantees it fits an empty axis)
            return !self.queue.is_empty();
        }
        self.step_no += 1;
        if self.faults.as_ref().map(|p| p.kill_at_step(self.step_no)).unwrap_or(false) {
            self.abort_session(sess);
            return true;
        }

        // assemble the step: active rows feed their next prompt token
        // (prefill phase) or their last generated token; free slots feed
        // masked padding
        let b = sess.rows.len();
        let mut toks = vec![0i32; b];
        let mut valid = vec![false; b];
        let mut poison = vec![false; b];
        let mut any_poison = false;
        for (slot, row) in sess.rows.iter().enumerate() {
            if let Some(r) = row {
                valid[slot] = true;
                toks[slot] = if r.pos < r.prompt.len() { r.prompt[r.pos] } else { r.last };
                if let Some(p) = &self.faults {
                    if p.poison_logits(r.id, r.fed + 1) {
                        poison[slot] = true;
                        any_poison = true;
                    }
                }
            }
        }
        let model: &ServeModel =
            if sess.dense { self.fallback.unwrap_or(self.primary) } else { self.primary };
        let t_step = std::time::Instant::now();
        let res = model.decode_step(
            &toks,
            &valid,
            &mut sess.cache,
            &mut sess.scratch,
            if any_poison { Some(&poison) } else { None },
        );
        crate::obs::hist_record(
            "gateway.decode_step_us",
            t_step.elapsed().as_secs_f64() * 1e6,
        );
        if let Some(ms) = self.faults.as_ref().and_then(|p| p.slow_step(self.step_no)) {
            self.clock.advance_ms(ms);
        }

        match res {
            Err(e) => {
                // batch-wide failure (KV capacity): every active row gets
                // the typed error; admission should make this unreachable,
                // but "should" is not a failure policy
                for slot in 0..b {
                    if let Some(r) = sess.rows[slot].take() {
                        self.finish(r.id, RequestOutcome::Failed(e.clone()));
                    }
                }
            }
            Ok(step) => {
                for slot in 0..b {
                    let Some(mut r) = sess.rows[slot].take() else { continue };
                    r.fed += 1;
                    if step.poisoned[slot] {
                        let packed = !sess.dense;
                        self.handle_poisoned(r, slot, packed);
                        continue; // slot freed for the next joiner
                    }
                    let tok = step.toks[slot];
                    if r.pos < r.prompt.len() {
                        r.pos += 1;
                        if r.pos == r.prompt.len() {
                            // prefill capture: seed for the first decode
                            // step, not an output token — same convention
                            // as `generate` (outputs are the max_new
                            // decode-loop tokens)
                            r.last = tok;
                        }
                    } else {
                        r.out.push(tok);
                        r.last = tok;
                    }
                    if r.out.len() >= r.max_new {
                        if !sess.dense {
                            self.breaker.record_success();
                        }
                        let latency = self.clock.now_ms().saturating_sub(r.submit_ms);
                        let degraded = sess.dense;
                        self.finish(
                            r.id,
                            RequestOutcome::Completed {
                                tokens: r.out,
                                latency_ms: latency,
                                degraded,
                            },
                        );
                    } else {
                        sess.rows[slot] = Some(r);
                    }
                }
                self.session = Some(sess);
            }
        }
        true
    }

    fn new_session(&self) -> Session {
        let b = self.cfg.max_batch.max(1);
        let budget = self.cfg.kv_slot_budget.max(1);
        let dense = self.degraded && self.fallback.is_some();
        let cfg = &self.primary.cfg;
        Session {
            cache: KvCache::with_limits(cfg, b, budget.min(64), budget),
            scratch: DecodeScratch::new(cfg, b),
            rows: (0..b).map(|_| None).collect(),
            dense,
        }
    }

    /// Fail queued requests whose deadline expired before they ever ran.
    fn expire_queue(&mut self) {
        let now = self.clock.now_ms();
        let q = std::mem::take(&mut self.queue);
        for a in q {
            let expired = a
                .deadline_ms
                .map(|d| now.saturating_sub(a.submit_ms) > d)
                .unwrap_or(false);
            if expired {
                self.finish(
                    a.id,
                    RequestOutcome::DeadlineMissed { generated: 0, stage: DeadlineStage::Queue },
                );
            } else {
                self.queue.push_back(a);
            }
        }
    }

    /// Move queued requests into free row slots, FIFO, while they fit
    /// the session's remaining KV time axis. A recycled slot's validity
    /// column is cleared first, so the joiner is isolated from its
    /// predecessor by construction.
    fn fill_rows(&mut self, sess: &mut Session) {
        let now = self.clock.now_ms();
        for slot in 0..sess.rows.len() {
            if sess.rows[slot].is_some() {
                continue;
            }
            let fits = match self.queue.front() {
                Some(head) => {
                    sess.cache.len + head.prompt.len() + head.max_new
                        <= self.cfg.kv_slot_budget
                }
                None => break,
            };
            if !fits {
                // FIFO: no overtaking; the head waits for a fresh axis
                break;
            }
            let a = self.queue.pop_front().expect("checked front");
            sess.cache.reset_row(slot);
            self.ledger.reserve(a.id, a.prompt.len() + a.max_new);
            crate::obs::hist_record(
                "gateway.time_in_queue_ms",
                now.saturating_sub(a.submit_ms) as f64,
            );
            sess.rows[slot] = Some(RowState {
                id: a.id,
                prompt: a.prompt,
                max_new: a.max_new,
                deadline_ms: a.deadline_ms,
                submit_ms: a.submit_ms,
                fed: 0,
                pos: 0,
                out: Vec::new(),
                last: 0,
                requeued: a.requeued,
            });
        }
    }

    /// Evict in-flight rows past their deadline. Survivors are
    /// untouched: an evicted row simply stops being fed, and its mask
    /// column was never visible to any other row.
    fn evict_expired(&mut self, sess: &mut Session) {
        let now = self.clock.now_ms();
        for slot in 0..sess.rows.len() {
            let expired = sess.rows[slot].as_ref().map(|r| r.expired(now)).unwrap_or(false);
            if expired {
                let r = sess.rows[slot].take().expect("checked some");
                self.finish(
                    r.id,
                    RequestOutcome::DeadlineMissed {
                        generated: r.out.len(),
                        stage: DeadlineStage::Decode,
                    },
                );
            }
        }
    }

    /// Simulated engine crash mid-session (injected kill): in-flight
    /// rows get one requeue (deterministic greedy decode reproduces the
    /// exact prefix, so the discarded partial output is lossless); a
    /// second abort fails them typed.
    fn abort_session(&mut self, mut sess: Session) {
        let mut requeued = 0usize;
        let mut failed = 0usize;
        for slot in 0..sess.rows.len() {
            if let Some(r) = sess.rows[slot].take() {
                self.ledger.release(r.id);
                if r.requeued {
                    failed += 1;
                    self.finish(r.id, RequestOutcome::Failed(ServeError::SessionAborted));
                } else {
                    requeued += 1;
                    self.counters.requeued += 1;
                    self.queue.push_front(Admitted {
                        id: r.id,
                        prompt: r.prompt,
                        max_new: r.max_new,
                        deadline_ms: r.deadline_ms,
                        submit_ms: r.submit_ms,
                        requeued: true,
                    });
                }
            }
        }
        crate::obs::warn(
            "gateway_session_abort",
            &format!(
                "[gateway] session aborted at step {}: {requeued} requeued, {failed} failed",
                self.step_no
            ),
            &[
                ("step", self.step_no.into()),
                ("requeued", requeued.into()),
                ("failed", failed.into()),
            ],
        );
    }

    /// A row's logits came back non-finite. On the packed path: count
    /// it against the breaker and retry the request on the dense
    /// fallback under the robust retry policy; otherwise fail it typed.
    fn handle_poisoned(&mut self, r: RowState, slot: usize, packed: bool) {
        let step = r.fed;
        if packed {
            if self.breaker.record_failure() && self.fallback.is_some() {
                self.degraded = true;
                crate::obs::warn(
                    "gateway_degrade",
                    &format!(
                        "[gateway] circuit breaker tripped after repeated packed-path \
                         failures: all sessions fall back to {}",
                        self.fallback.map(|f| f.label.as_str()).unwrap_or("?")
                    ),
                    &[("scope", "gateway".into()), ("request", r.id.into())],
                );
            }
            if let Some(fb) = self.fallback {
                crate::obs::event(
                    "gateway_degrade",
                    &[("scope", "request".into()), ("request", r.id.into()), ("step", step.into())],
                );
                let now = self.clock.now_ms();
                let expired = r
                    .deadline_ms
                    .map(|d| now.saturating_sub(r.submit_ms) > d)
                    .unwrap_or(false);
                if expired {
                    self.finish(
                        r.id,
                        RequestOutcome::DeadlineMissed {
                            generated: r.out.len(),
                            stage: DeadlineStage::Decode,
                        },
                    );
                    return;
                }
                let prompt = &r.prompt;
                let max_new = r.max_new;
                let res = with_retry(&self.cfg.retry, "gateway dense fallback", || {
                    let (mut outs, _) = fb.generate(std::slice::from_ref(prompt), max_new)?;
                    Ok(outs.remove(0))
                });
                match res {
                    Ok(tokens) => {
                        let latency = self.clock.now_ms().saturating_sub(r.submit_ms);
                        self.finish(
                            r.id,
                            RequestOutcome::Completed {
                                tokens,
                                latency_ms: latency,
                                degraded: true,
                            },
                        );
                    }
                    Err(e) => {
                        self.finish(
                            r.id,
                            RequestOutcome::Failed(ServeError::FallbackFailed(format!(
                                "{e:#}"
                            ))),
                        );
                    }
                }
                return;
            }
        }
        self.finish(r.id, RequestOutcome::Failed(ServeError::PoisonedLogits { row: slot, step }));
    }

    /// Record a terminal outcome: release KV accounting, bump counters,
    /// emit telemetry. Every admitted request passes through here
    /// exactly once (request conservation).
    fn finish(&mut self, id: u64, outcome: RequestOutcome) {
        self.ledger.release(id);
        match &outcome {
            RequestOutcome::Completed { latency_ms, degraded, tokens } => {
                self.counters.completed += 1;
                if *degraded {
                    self.counters.degraded += 1;
                }
                crate::obs::hist_record("gateway.request_latency_ms", *latency_ms as f64);
                crate::obs::event(
                    "gateway_complete",
                    &[
                        ("id", id.into()),
                        ("tokens", tokens.len().into()),
                        ("latency_ms", (*latency_ms).into()),
                        ("degraded", (*degraded).into()),
                    ],
                );
            }
            RequestOutcome::DeadlineMissed { generated, stage } => {
                self.counters.deadline_missed += 1;
                crate::obs::event(
                    "gateway_deadline_miss",
                    &[
                        ("id", id.into()),
                        ("stage", stage.tag().into()),
                        ("generated", (*generated).into()),
                    ],
                );
            }
            RequestOutcome::Failed(e) => {
                self.counters.failed += 1;
                crate::obs::warn(
                    "gateway_request_failed",
                    &format!("[gateway] request {id} failed: {e}"),
                    &[("id", id.into()), ("error", format!("{e}").into())],
                );
            }
        }
        let prev = self.outcomes.insert(id, outcome);
        debug_assert!(prev.is_none(), "double outcome for request {id}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, Params};
    use crate::serve::PrefillMode;
    use crate::tensor::Pcg32;

    fn nano(seed: u64) -> (ModelConfig, Params) {
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(seed);
        let p = Params::init(&cfg, &mut rng);
        (cfg, p)
    }

    fn solo(m: &ServeModel, prompt: &[i32], new: usize) -> Vec<i32> {
        let (mut outs, _) =
            m.generate_with(&[prompt.to_vec()], new, PrefillMode::PerToken).unwrap();
        outs.remove(0)
    }

    #[test]
    fn sheds_on_queue_full_kv_budget_and_invalid() {
        let (_, p) = nano(20);
        let m = ServeModel::dense(&p);
        let cfg = GatewayConfig {
            queue_depth: 2,
            max_batch: 1,
            kv_slot_budget: 16,
            ..Default::default()
        };
        let mut gw = Gateway::new(&m, cfg);
        assert!(gw.submit(Request::new(vec![1, 2], 4)).is_ok());
        assert!(gw.submit(Request::new(vec![3, 4], 4)).is_ok());
        match gw.submit(Request::new(vec![5, 6], 4)) {
            Err(ShedReason::QueueFull { depth: 2 }) => {}
            other => panic!("expected QueueFull, got {other:?}"),
        }
        match gw.submit(Request::new(vec![1; 10], 10)) {
            Err(ShedReason::KvBudget { need: 20, budget: 16 }) => {}
            other => panic!("expected KvBudget, got {other:?}"),
        }
        match gw.submit(Request::new(vec![], 4)) {
            Err(ShedReason::InvalidPrompt(_)) => {}
            other => panic!("expected InvalidPrompt, got {other:?}"),
        }
        match gw.submit(Request::new(vec![100_000], 4)) {
            Err(ShedReason::InvalidPrompt(_)) => {}
            other => panic!("expected InvalidPrompt (vocab), got {other:?}"),
        }
        let c = gw.counters();
        assert_eq!(c.submitted, 6);
        assert_eq!(c.admitted, 2);
        assert_eq!(c.shed, 4);
    }

    #[test]
    fn continuous_batching_is_bit_identical_to_solo() {
        // more requests than row slots: later requests join mid-session
        // as slots free up (recycled columns), and every output must
        // equal its solo run exactly
        let (_, p) = nano(21);
        let m = ServeModel::dense(&p);
        let cfg = GatewayConfig {
            queue_depth: 16,
            max_batch: 2,
            kv_slot_budget: 256,
            ..Default::default()
        };
        let mut gw = Gateway::new(&m, cfg);
        let reqs: Vec<(Vec<i32>, usize)> = vec![
            (vec![3, 17, 40, 9], 6),
            (vec![12, 7], 3),
            (vec![1, 2, 3, 4, 5], 5),
            (vec![60, 61], 8),
            (vec![9, 9, 9], 2),
        ];
        let ids: Vec<u64> = reqs
            .iter()
            .map(|(p, n)| gw.submit(Request::new(p.clone(), *n)).unwrap())
            .collect();
        gw.drain();
        assert!(gw.idle());
        assert_eq!(gw.kv_in_use(), 0, "leaked KV reservations");
        for (id, (prompt, new)) in ids.iter().zip(&reqs) {
            match &gw.outcomes()[id] {
                RequestOutcome::Completed { tokens, degraded: false, .. } => {
                    assert_eq!(tokens, &solo(&m, prompt, *new), "request {id} diverged");
                }
                other => panic!("request {id}: expected completion, got {other:?}"),
            }
        }
        assert_eq!(gw.counters().completed, 5);
    }

    #[test]
    fn deadline_eviction_keeps_survivors_exact() {
        use crate::robust::FaultPlan;
        let (_, p) = nano(22);
        let m = ServeModel::dense(&p);
        let cfg = GatewayConfig {
            queue_depth: 8,
            max_batch: 2,
            kv_slot_budget: 256,
            ..Default::default()
        };
        // decode step 3 "takes" 10^7 ms of synthetic time: the 5s-deadline
        // row must evict, the unbounded row must finish bit-exact
        let plan = Rc::new(FaultPlan::parse("slow@3.10000000").unwrap());
        let mut gw = Gateway::new(&m, cfg).with_faults(plan);
        let survivor = vec![3i32, 17, 40, 9, 22, 5];
        let victim = vec![12i32, 7, 44];
        let sid = gw.submit(Request::new(survivor.clone(), 8)).unwrap();
        let vid = gw.submit(Request::new(victim.clone(), 8).with_deadline(5_000)).unwrap();
        gw.drain();
        match &gw.outcomes()[&vid] {
            RequestOutcome::DeadlineMissed { stage: DeadlineStage::Decode, .. } => {}
            other => panic!("victim: expected decode-stage miss, got {other:?}"),
        }
        match &gw.outcomes()[&sid] {
            RequestOutcome::Completed { tokens, .. } => {
                assert_eq!(tokens, &solo(&m, &survivor, 8), "survivor perturbed by eviction");
            }
            other => panic!("survivor: expected completion, got {other:?}"),
        }
        assert_eq!(gw.kv_in_use(), 0);
    }

    #[test]
    fn queue_deadline_expires_without_running() {
        use crate::robust::FaultPlan;
        let (_, p) = nano(23);
        let m = ServeModel::dense(&p);
        let cfg =
            GatewayConfig { max_batch: 1, kv_slot_budget: 256, ..Default::default() };
        // the stall hits pump 1 before any decode step runs
        let plan = Rc::new(FaultPlan::parse("stall@1.10000000").unwrap());
        let mut gw = Gateway::new(&m, cfg).with_faults(plan);
        let id = gw.submit(Request::new(vec![1, 2, 3], 4).with_deadline(1_000)).unwrap();
        gw.drain();
        match &gw.outcomes()[&id] {
            RequestOutcome::DeadlineMissed { generated: 0, stage: DeadlineStage::Queue } => {}
            other => panic!("expected queue-stage miss, got {other:?}"),
        }
    }

    #[test]
    fn breaker_trips_and_degrades_to_dense() {
        use crate::robust::FaultPlan;
        let (_, p) = nano(24);
        let packed = ServeModel::packed_rtn(&p, 2).unwrap();
        let dense = ServeModel::dense(&p);
        let cfg = GatewayConfig {
            queue_depth: 8,
            max_batch: 1,
            kv_slot_budget: 256,
            breaker_threshold: 2,
            ..Default::default()
        };
        // poison requests 0 and 1 at their first step on the packed path
        let plan = Rc::new(FaultPlan::parse("poison@0.1,poison@1.1").unwrap());
        let mut gw = Gateway::new(&packed, cfg).with_fallback(&dense).with_faults(plan);
        let prompts =
            [vec![3i32, 17, 40], vec![12i32, 7, 44, 9], vec![1i32, 2, 3, 4]];
        let ids: Vec<u64> =
            prompts.iter().map(|p| gw.submit(Request::new(p.clone(), 4)).unwrap()).collect();
        gw.drain();
        assert!(gw.is_degraded(), "two consecutive packed failures must trip the breaker");
        // poisoned requests completed degraded on the dense fallback
        for (i, id) in ids.iter().take(2).enumerate() {
            match &gw.outcomes()[id] {
                RequestOutcome::Completed { tokens, degraded: true, .. } => {
                    assert_eq!(tokens, &solo(&dense, &prompts[i], 4));
                }
                other => panic!("request {id}: expected degraded completion, got {other:?}"),
            }
        }
        // the third ran after the trip: whole session on the dense path
        match &gw.outcomes()[&ids[2]] {
            RequestOutcome::Completed { tokens, degraded: true, .. } => {
                assert_eq!(tokens, &solo(&dense, &prompts[2], 4));
            }
            other => panic!("post-trip request: expected dense completion, got {other:?}"),
        }
        assert_eq!(gw.counters().degraded, 3);
        assert_eq!(gw.kv_in_use(), 0);
    }

    #[test]
    fn poisoned_row_without_fallback_fails_typed() {
        use crate::robust::FaultPlan;
        let (_, p) = nano(25);
        let m = ServeModel::dense(&p);
        let cfg =
            GatewayConfig { max_batch: 2, kv_slot_budget: 256, ..Default::default() };
        let plan = Rc::new(FaultPlan::parse("poison@1.2").unwrap());
        let mut gw = Gateway::new(&m, cfg).with_faults(plan);
        let ok = gw.submit(Request::new(vec![3, 17, 40, 9], 5)).unwrap();
        let bad = gw.submit(Request::new(vec![12, 7, 44], 5)).unwrap();
        gw.drain();
        match &gw.outcomes()[&bad] {
            RequestOutcome::Failed(ServeError::PoisonedLogits { step: 2, .. }) => {}
            other => panic!("expected PoisonedLogits at step 2, got {other:?}"),
        }
        match &gw.outcomes()[&ok] {
            RequestOutcome::Completed { tokens, .. } => {
                assert_eq!(tokens, &solo(&m, &[3, 17, 40, 9], 5), "healthy row perturbed");
            }
            other => panic!("expected completion, got {other:?}"),
        }
    }

    #[test]
    fn session_kill_requeues_once_then_fails() {
        use crate::robust::FaultPlan;
        let (_, p) = nano(26);
        let m = ServeModel::dense(&p);
        let cfg =
            GatewayConfig { max_batch: 2, kv_slot_budget: 256, ..Default::default() };
        // kill the session at global decode steps 2 AND 4: the requeued
        // requests die a second time and must fail typed
        let plan = Rc::new(FaultPlan::parse("kill@2,kill@4").unwrap());
        let mut gw = Gateway::new(&m, cfg).with_faults(plan);
        let a = gw.submit(Request::new(vec![3, 17, 40, 9], 4)).unwrap();
        let b = gw.submit(Request::new(vec![12, 7], 4)).unwrap();
        gw.drain();
        for id in [a, b] {
            match &gw.outcomes()[&id] {
                RequestOutcome::Failed(ServeError::SessionAborted) => {}
                other => panic!("request {id}: expected SessionAborted, got {other:?}"),
            }
        }
        assert_eq!(gw.counters().requeued, 2);
        assert_eq!(gw.kv_in_use(), 0);
        // single kill: requests recover via requeue and complete exactly
        let plan2 = Rc::new(FaultPlan::parse("kill@2").unwrap());
        let cfg2 =
            GatewayConfig { max_batch: 2, kv_slot_budget: 256, ..Default::default() };
        let mut gw2 = Gateway::new(&m, cfg2).with_faults(plan2);
        let a2 = gw2.submit(Request::new(vec![3, 17, 40, 9], 4)).unwrap();
        gw2.drain();
        match &gw2.outcomes()[&a2] {
            RequestOutcome::Completed { tokens, .. } => {
                assert_eq!(tokens, &solo(&m, &[3, 17, 40, 9], 4));
            }
            other => panic!("expected post-requeue completion, got {other:?}"),
        }
    }
}
