//! Parser for `artifacts/manifest.json` — the machine-readable contract
//! emitted by python/compile/aot.py describing every HLO artifact's
//! positional inputs/outputs and the model/quant metadata.
//!
//! Decoded with the in-tree JSON parser (offline environment, no serde).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
    pub param_names: Vec<String>,
    pub linear_names: Vec<String>,
    /// Directory the manifest was loaded from (None when parsed from a
    /// string); used to point lookup errors at the searched location.
    pub dir: Option<PathBuf>,
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<String>,
    pub meta: Meta,
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct Meta {
    pub size: String,
    pub kind: String,
    pub scheme: Option<String>,
    pub batch: Option<usize>,
    pub bits: Option<u32>,
    pub group: Option<usize>,
    pub model: ModelMeta,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub calib_batch: usize,
    pub sat_nu: f32,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub max_seq: usize,
    pub rope_theta: f64,
    pub norm_eps: f64,
}

fn strings(j: &Json) -> Result<Vec<String>> {
    j.as_arr()?.iter().map(|v| Ok(v.as_str()?.to_string())).collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let mut m =
            Self::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        m.dir = Some(dir.to_path_buf());
        Ok(m)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let artifacts = j
            .get("artifacts")?
            .as_arr()?
            .iter()
            .map(ArtifactSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            artifacts,
            param_names: strings(j.get("param_names")?)?,
            linear_names: strings(j.get("linear_names")?)?,
            dir: None,
        })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name).with_context(|| {
            let known: Vec<_> = self.artifacts.iter().map(|a| a.name.as_str()).collect();
            let whence = match &self.dir {
                Some(d) => format!(" (searched {})", d.display()),
                None => String::new(),
            };
            format!("artifact {name:?} not in manifest{whence}; known: {known:?}")
        })
    }

    /// Artifacts grouped by (kind, size).
    pub fn by_kind(&self) -> HashMap<(String, String), Vec<&ArtifactSpec>> {
        let mut map: HashMap<(String, String), Vec<&ArtifactSpec>> = HashMap::new();
        for a in &self.artifacts {
            map.entry((a.meta.kind.clone(), a.meta.size.clone())).or_default().push(a);
        }
        map
    }
}

impl ArtifactSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let inputs = j
            .get("inputs")?
            .as_arr()?
            .iter()
            .map(|io| {
                Ok(IoSpec {
                    name: io.get("name")?.as_str()?.to_string(),
                    shape: io
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<_>>()?,
                    dtype: io.get("dtype")?.as_str()?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ArtifactSpec {
            name: j.get("name")?.as_str()?.to_string(),
            path: j.get("path")?.as_str()?.to_string(),
            inputs,
            outputs: strings(j.get("outputs")?)?,
            meta: Meta::from_json(j.get("meta")?)?,
        })
    }

    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|i| i.name == name)
            .with_context(|| format!("{}: no input named {name:?}", self.name))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|o| o == name)
            .with_context(|| format!("{}: no output named {name:?}", self.name))
    }
}

impl Meta {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(Meta {
            size: j.get("size")?.as_str()?.to_string(),
            kind: j.get("kind")?.as_str()?.to_string(),
            scheme: j.opt("scheme").map(|v| v.as_str().map(str::to_string)).transpose()?,
            batch: j.opt("batch").map(|v| v.as_usize()).transpose()?,
            bits: j.opt("bits").map(|v| Ok::<u32, anyhow::Error>(v.as_f64()? as u32)).transpose()?,
            group: j.opt("group").map(|v| v.as_usize()).transpose()?,
            model: ModelMeta::from_json(j.get("model")?)?,
            train_batch: j.get("train_batch")?.as_usize()?,
            eval_batch: j.get("eval_batch")?.as_usize()?,
            calib_batch: j.get("calib_batch")?.as_usize()?,
            sat_nu: j.get("sat_nu")?.as_f64()? as f32,
        })
    }
}

impl ModelMeta {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(ModelMeta {
            name: j.get("name")?.as_str()?.to_string(),
            vocab_size: j.get("vocab_size")?.as_usize()?,
            d_model: j.get("d_model")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            n_kv_heads: j.get("n_kv_heads")?.as_usize()?,
            d_ff: j.get("d_ff")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            max_seq: j.get("max_seq")?.as_usize()?,
            rope_theta: j.get("rope_theta")?.as_f64()?,
            norm_eps: j.get("norm_eps")?.as_f64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": [{
        "name": "block_fp_fwd.nano", "path": "block_fp_fwd.nano.hlo.txt",
        "inputs": [{"name": "x", "shape": [4, 64, 64], "dtype": "float32"}],
        "outputs": ["y"],
        "meta": {"size": "nano", "kind": "block_fp_fwd", "batch": 4,
                 "model": {"name": "nano", "vocab_size": 128, "d_model": 64,
                           "n_heads": 2, "n_kv_heads": 2, "d_ff": 192,
                           "n_layers": 2, "max_seq": 64,
                           "rope_theta": 10000.0, "norm_eps": 1e-5},
                 "train_batch": 8, "eval_batch": 8, "calib_batch": 4,
                 "sat_nu": 100.0}
      }],
      "param_names": ["emb"], "linear_names": ["q_proj"]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.get("block_fp_fwd.nano").unwrap();
        assert_eq!(a.inputs[0].shape, vec![4, 64, 64]);
        assert_eq!(a.meta.model.d_ff, 192);
        assert_eq!(a.meta.sat_nu, 100.0);
        assert!(a.meta.scheme.is_none());
        assert_eq!(a.input_index("x").unwrap(), 0);
        assert!(a.input_index("nope").is_err());
    }

    #[test]
    fn unknown_artifact_lists_known() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let err = format!("{:#}", m.get("missing").unwrap_err());
        assert!(err.contains("block_fp_fwd.nano"));
    }

    #[test]
    fn unknown_artifact_names_searched_dir() {
        let mut m = Manifest::parse(SAMPLE).unwrap();
        m.dir = Some(PathBuf::from("/some/artifacts"));
        let err = format!("{:#}", m.get("missing").unwrap_err());
        assert!(err.contains("/some/artifacts"), "{err}");
        assert!(err.contains("block_fp_fwd.nano"), "{err}");
    }
}
