//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the calibration/eval hot paths.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` ->
//! `XlaComputation::from_proto` -> `PjRtClient::compile`. Inputs are
//! uploaded as device buffers (`buffer_from_host_buffer`) and executed via
//! `execute_b`; the (single, tupled) output is decomposed back into host
//! tensors. Executables are cached per artifact name.

pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::robust::FaultPlan;
use crate::tensor::Tensor;
pub use manifest::{ArtifactSpec, Manifest, Meta, ModelMeta};

/// Positional argument for an artifact call.
pub enum Arg<'a> {
    F32(&'a Tensor),
    I32(&'a [i32], &'a [usize]),
    Scalar(f32),
}

pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Artifact>>>,
    /// Cumulative (compile_ms, exec_calls) for profiling.
    pub stats: RefCell<EngineStats>,
    /// Deterministic fault injection (tests / resilience drills).
    faults: RefCell<Option<Rc<FaultPlan>>>,
}

#[derive(Default, Debug, Clone)]
pub struct EngineStats {
    pub compile_ms: f64,
    pub exec_calls: u64,
    pub upload_bytes: u64,
    pub download_bytes: u64,
}

pub struct Artifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Engine {
    pub fn new(dir: PathBuf) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(EngineStats::default()),
            faults: RefCell::new(FaultPlan::from_env()),
        })
    }

    /// Install (or clear) a fault-injection plan for this engine's
    /// compile/execute paths. `Engine::new` picks one up automatically
    /// from `TESSERAQ_FAULTS`.
    pub fn set_fault_plan(&self, plan: Option<Rc<FaultPlan>>) {
        *self.faults.borrow_mut() = plan;
    }

    pub fn from_default_dir() -> Result<Self> {
        Self::new(crate::default_artifact_dir())
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn artifact(&self, name: &str) -> Result<Rc<Artifact>> {
        if let Some(a) = self.cache.borrow().get(name) {
            return Ok(a.clone());
        }
        if let Some(plan) = self.faults.borrow().as_ref() {
            if let Some(e) = plan.fail_compile(name) {
                return Err(e);
            }
        }
        let spec = self.manifest.get(name)?.clone();
        let path = self.dir.join(&spec.path);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("loading HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        self.stats.borrow_mut().compile_ms += t0.elapsed().as_secs_f64() * 1e3;
        let art = Rc::new(Artifact { spec, exe });
        self.cache.borrow_mut().insert(name.to_string(), art.clone());
        Ok(art)
    }

    /// Upload a tensor as an f32 device buffer.
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        self.stats.borrow_mut().upload_bytes += (t.data.len() * 4) as u64;
        self.client
            .buffer_from_host_buffer(&t.data, &t.shape, None)
            .context("uploading f32 buffer")
    }

    pub fn upload_i32(&self, data: &[i32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        self.stats.borrow_mut().upload_bytes += (data.len() * 4) as u64;
        self.client
            .buffer_from_host_buffer(data, shape, None)
            .context("uploading i32 buffer")
    }

    pub fn upload_scalar(&self, v: f32) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&[v], &[], None)
            .context("uploading scalar")
    }

    fn upload_arg(&self, a: &Arg) -> Result<xla::PjRtBuffer> {
        match a {
            Arg::F32(t) => self.upload(t),
            Arg::I32(d, s) => self.upload_i32(d, s),
            Arg::Scalar(v) => self.upload_scalar(*v),
        }
    }

    /// Execute an artifact with host args; returns host tensors.
    pub fn run(&self, art: &Artifact, args: &[Arg]) -> Result<Vec<Tensor>> {
        let bufs = self.upload_args(art, args)?;
        self.run_buffers(art, &bufs)
    }

    /// Validate shapes and upload all args as device buffers.
    pub fn upload_args(&self, art: &Artifact, args: &[Arg]) -> Result<Vec<xla::PjRtBuffer>> {
        let spec = &art.spec;
        if args.len() != spec.inputs.len() {
            bail!(
                "{}: got {} args, expected {}",
                spec.name,
                args.len(),
                spec.inputs.len()
            );
        }
        let mut bufs = Vec::with_capacity(args.len());
        for (i, (a, io)) in args.iter().zip(&spec.inputs).enumerate() {
            let (shape, dtype): (Vec<usize>, &str) = match a {
                Arg::F32(t) => (t.shape.clone(), "float32"),
                Arg::I32(_, s) => (s.to_vec(), "int32"),
                Arg::Scalar(_) => (vec![], "float32"),
            };
            if shape != io.shape || dtype != io.dtype {
                bail!(
                    "{} input #{i} ({}): got {:?}/{}, expected {:?}/{}",
                    spec.name, io.name, shape, dtype, io.shape, io.dtype
                );
            }
            bufs.push(self.upload_arg(a)?);
        }
        Ok(bufs)
    }

    /// Execute with pre-uploaded device buffers (hot-loop path).
    pub fn run_buffers<L: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self,
        art: &Artifact,
        bufs: &[L],
    ) -> Result<Vec<Tensor>> {
        self.stats.borrow_mut().exec_calls += 1;
        if let Some(plan) = self.faults.borrow().as_ref() {
            if let Some(e) = plan.fail_exec(&art.spec.name) {
                return Err(e);
            }
        }
        let outs = art.exe.execute_b(bufs).with_context(|| format!("executing {}", art.spec.name))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .context("downloading result")?;
        // aot.py lowers with return_tuple=True: single tuple output.
        let parts = lit.to_tuple().context("decomposing result tuple")?;
        let mut tensors = Vec::with_capacity(parts.len());
        let mut dl = 0u64;
        for p in parts {
            let shape = p.array_shape().context("output shape")?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = p.to_vec::<f32>().context("output to_vec")?;
            dl += (data.len() * 4) as u64;
            tensors.push(Tensor::new(dims, data));
        }
        self.stats.borrow_mut().download_bytes += dl;
        Ok(tensors)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

impl Artifact {
    pub fn name(&self) -> &str {
        &self.spec.name
    }
}
