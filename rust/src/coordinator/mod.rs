//! The TesseraQ calibration coordinator — the paper's system contribution
//! at L3. [`driver`] owns the one resumable, sentinel-guarded block-loop
//! skeleton every reconstruction method runs through; [`par`] (TesseraQ),
//! [`lwc`] (OmniQuant) and the GPTQ optimizer in [`driver`] plug into it
//! as `BlockOptimizer`s. The per-step math executes inside AOT artifacts
//! (block_par_step / block_lwc_step / block_fp_fwd).

pub mod driver;
pub mod lwc;
pub mod par;
pub mod pipeline;
pub mod pretrain;
pub mod schedule;

pub use driver::{
    BlockOptimizer, BlockStatus, BlockTrace, CalibReport, ReconstructionDriver,
};
pub use par::{calibrate_tesseraq, calibrate_tesseraq_robust, TesseraqConfig};
pub use pipeline::ForwardBackend;
pub use schedule::Schedule;
