//! The TesseraQ calibration coordinator — the paper's system contribution
//! at L3. Owns the block-wise reconstruction pipeline: teacher forwards,
//! PAR harden/soften scheduling, DST, merging, and the OmniQuant-LWC
//! baseline driver. The per-step math executes inside AOT artifacts
//! (block_par_step / block_lwc_step / block_fp_fwd).

pub mod lwc;
pub mod par;
pub mod pipeline;
pub mod pretrain;
pub mod schedule;

pub use par::{
    calibrate_tesseraq, calibrate_tesseraq_robust, BlockStatus, BlockTrace, CalibReport,
    TesseraqConfig,
};
pub use pipeline::ForwardBackend;
pub use schedule::Schedule;
