//! PAR harden-phase schedules (paper §3.2 + Fig. 3 ablation).
//!
//! A schedule maps iteration progress x = k/K to the *soft rate* — the
//! fraction of rounding variables still soft. The paper's guidance:
//! increase the hard percentage rapidly early, slowly later, and reach
//! (nearly) 100% hard by the last iteration.

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// Paper's handcrafted decay (geometric-ish, Fig. 3 right).
    Handcrafted,
    /// Rule-based 1/exp(t*x) with temperature t (Fig. 3 ablation).
    ExpTemp(f32),
    /// Linear decay (a deliberately bad control for the ablation).
    Linear,
}

impl Schedule {
    /// Soft rate entering iteration k of `total` (k = 1..=total): the
    /// fraction of variables kept soft during that iteration's soften
    /// phase. Starts at 1.0 ("starting from an empty hard rounding set",
    /// paper §3.2) and is 0 at k == total so the final soften phase only
    /// polishes the DST scales before the merge.
    pub fn soft_rate(&self, k: usize, total: usize) -> f32 {
        assert!(k >= 1 && k <= total);
        if k == 1 {
            return 1.0;
        }
        if k == total {
            return 0.0;
        }
        let x = (k - 1) as f32 / total as f32;
        match self {
            // fast-then-slow geometric decay: halves roughly every 12%
            // of the run early on, creeping near zero by the end.
            Schedule::Handcrafted => 0.5f32.powf(6.0 * x) * (1.0 - x).max(0.0),
            Schedule::ExpTemp(t) => (-t * x).exp(),
            Schedule::Linear => 1.0 - x,
        }
    }

    pub fn label(&self) -> String {
        match self {
            Schedule::Handcrafted => "handcrafted".into(),
            Schedule::ExpTemp(t) => format!("exp(t={t})"),
            Schedule::Linear => "linear".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_decreasing_and_terminal_zero() {
        for sched in [Schedule::Handcrafted, Schedule::ExpTemp(4.0), Schedule::Linear] {
            let k_total = 20;
            let mut prev = 1.0f32;
            for k in 1..=k_total {
                let r = sched.soft_rate(k, k_total);
                assert!(r <= prev + 1e-6, "{sched:?} not monotone at {k}");
                assert!((0.0..=1.0).contains(&r));
                prev = r;
            }
            assert_eq!(sched.soft_rate(k_total, k_total), 0.0);
        }
    }

    #[test]
    fn handcrafted_decays_fast_early_slow_late() {
        let s = Schedule::Handcrafted;
        let early_drop = s.soft_rate(1, 20) - s.soft_rate(5, 20);
        let late_drop = s.soft_rate(14, 20) - s.soft_rate(18, 20);
        assert!(
            early_drop > 4.0 * late_drop,
            "early {early_drop} vs late {late_drop}"
        );
    }

    #[test]
    fn temperature_orders_rates() {
        // higher temperature -> harder faster
        let k = 5;
        let r2 = Schedule::ExpTemp(2.0).soft_rate(k, 20);
        let r5 = Schedule::ExpTemp(5.0).soft_rate(k, 20);
        assert!(r5 < r2);
    }
}
