//! TesseraQ calibration: Progressive Adaptive Rounding + Dequantization
//! Scale Tuning over block-wise reconstruction (paper Algorithm 1).
//!
//! Host side owns the PAR state (nu, v, Adam moments) and the harden
//! phase (HS scoring + saturation at +-SAT_NU); each soften-phase step
//! executes the AOT `block_par_step` artifact. Hardened logits receive
//! exactly-zero gradients inside the artifact, so no masking is needed —
//! the paper's memory-efficient trick.

use std::collections::BTreeMap;

use anyhow::{ensure, Context, Result};

use crate::coordinator::pipeline::{BlockRunner, CalibSet};
use crate::coordinator::schedule::Schedule;
use crate::model::{Params, LINEAR_NAMES};
use crate::quant::{
    self, dequant_codes, dst_effective_scale, hard_codes, minmax_scale, nu_init,
    w_floor, ClipFactors, QParams, QuantConfig, SAT_NU,
};
use crate::runtime::Engine;
use crate::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct TesseraqConfig {
    pub qcfg: QuantConfig,
    /// PAR iterations (paper K = 20; scaled down for the tiny testbed).
    pub iterations: usize,
    /// Soften-phase Adam steps per iteration (paper T = 250).
    pub steps_per_iter: usize,
    pub lr: f32,
    pub schedule: Schedule,
    /// Ablation switches (Table 6).
    pub enable_par: bool,
    pub enable_dst: bool,
    /// Quantize the propagated stream with the target act bits.
    pub propagate_act_quant: bool,
    /// Artifact name suffix selecting a batch-size variant (Table 5),
    /// e.g. ".b1" -> block_par_step.<size>.<scheme>.b1.
    pub artifact_suffix: String,
}

impl TesseraqConfig {
    pub fn standard(qcfg: QuantConfig) -> Self {
        TesseraqConfig {
            qcfg,
            iterations: 8,
            steps_per_iter: 24,
            lr: 1e-2,
            schedule: Schedule::Handcrafted,
            enable_par: true,
            enable_dst: true,
            propagate_act_quant: false,
            artifact_suffix: String::new(),
        }
    }

    /// Fast preset for tests/CI.
    pub fn fast(qcfg: QuantConfig) -> Self {
        TesseraqConfig { iterations: 4, steps_per_iter: 8, ..Self::standard(qcfg) }
    }
}

/// Per-block calibration record (Fig. 4 traces + Table 7 flip stats).
#[derive(Debug, Clone)]
pub struct BlockTrace {
    pub layer: usize,
    /// reconstruction MSE after each soften step
    pub losses: Vec<f32>,
    /// per linear: (flipped vs RTN, total rounding variables)
    pub flips: BTreeMap<String, (usize, usize)>,
    /// loss right before any optimization (RTN-equivalent start)
    pub initial_loss: f32,
}

pub struct CalibReport {
    pub per_block: Vec<BlockTrace>,
    /// per block, per linear: final integer codes + effective dequant
    /// params (s_eff = 2*sigmoid(v)*s) — ready for packing/serving.
    pub quantized: Vec<BTreeMap<String, (Vec<u16>, QParams)>>,
    pub wall_s: f64,
}

struct LinearState {
    o: usize,
    i: usize,
    qp: QParams,
    wf: Tensor,
    nu: Tensor,
    v: Tensor,
    m_nu: Tensor,
    u_nu: Tensor,
    m_v: Tensor,
    u_v: Tensor,
}

impl LinearState {
    fn init(w: &Tensor, qp: QParams, hardened_start: bool) -> LinearState {
        let (o, i) = w.dims2();
        let wf = w_floor(w, &qp);
        let mut nu = nu_init(w, &qp);
        if hardened_start {
            for x in nu.data.iter_mut() {
                *x = if *x > 0.0 { SAT_NU } else { -SAT_NU };
            }
        }
        let gshape = qp.s.shape.clone();
        LinearState {
            o,
            i,
            wf,
            nu: nu.clone(),
            v: Tensor::zeros(&gshape),
            m_nu: Tensor::zeros(&nu.shape),
            u_nu: Tensor::zeros(&nu.shape),
            m_v: Tensor::zeros(&gshape),
            u_v: Tensor::zeros(&gshape),
            qp,
        }
    }
}

/// Optional per-linear clip factors from an initializer (AWQ / LWC).
pub type BlockClips = BTreeMap<String, (Tensor, Tensor)>;

/// Run TesseraQ over the whole model in place. `clips[l]` supplies the
/// (gamma, beta) per-group clip factors from the initializer (None ->
/// plain min/max). Weights in `params` must already carry any scale
/// transformation (AWQ fold) — exactly the paper's Fig. 1(a) flow.
pub fn calibrate_tesseraq(
    eng: &Engine,
    params: &mut Params,
    clips: Option<&[BlockClips]>,
    tokens: &[i32],
    n_seq: usize,
    tcfg: &TesseraqConfig,
) -> Result<CalibReport> {
    let t0 = std::time::Instant::now();
    let size = params.cfg.name.clone();
    let scheme = tcfg.qcfg.scheme.tag();
    let runner = BlockRunner::new(eng, &size)?;
    let step_art = eng
        .artifact(&format!("block_par_step.{size}.{scheme}{}", tcfg.artifact_suffix))
        .with_context(|| format!("no PAR artifact for {size}/{scheme}"))?;
    let batch = step_art.spec.meta.batch.unwrap_or(4);
    ensure!(n_seq % batch == 0, "n_seq {n_seq} not divisible by batch {batch}");

    let qmax_w = tcfg.qcfg.qmax_w();
    let qmax_act = tcfg.qcfg.qmax_act();
    let mut set = CalibSet::from_tokens(params, tokens, n_seq);
    let mut per_block = Vec::new();
    let mut quantized = Vec::new();

    for l in 0..params.cfg.n_layers {
        let bw = params.block(l);
        // teacher target on the (quantized-prefix) stream, FP weights
        let y_all = runner.forward_all(&bw, &set, quant::A16_SENTINEL)?;

        // per-linear PAR state
        let mut states: BTreeMap<String, LinearState> = BTreeMap::new();
        for name in LINEAR_NAMES {
            let w = &bw.linears[name];
            let g = tcfg.qcfg.scheme.group_size(w.shape[1]);
            let qp = match clips.and_then(|c| c[l].get(name)) {
                Some((gm, bt)) => minmax_scale(
                    w,
                    g,
                    &ClipFactors::PerGroup(gm.clone()),
                    &ClipFactors::PerGroup(bt.clone()),
                    qmax_w,
                ),
                None => minmax_scale(
                    w,
                    g,
                    &ClipFactors::Uniform(1.0),
                    &ClipFactors::Uniform(1.0),
                    qmax_w,
                ),
            };
            states.insert(name.to_string(), LinearState::init(w, qp, !tcfg.enable_par));
        }

        let total_vars: usize = states.values().map(|s| s.nu.data.len()).sum();
        let mut trace = BlockTrace {
            layer: l,
            losses: Vec::new(),
            flips: BTreeMap::new(),
            initial_loss: f32::NAN,
        };

        // per-block constants live on device for the whole PAR loop
        let consts = BlockConstBufs::new(eng, &bw.norm1, &bw.norm2, &states,
                                         qmax_w, qmax_act)?;

        // PAR loop
        let mut t_global = 0u32;
        for k in 1..=tcfg.iterations {
            if tcfg.enable_par {
                let soft = tcfg.schedule.soft_rate(k, tcfg.iterations);
                let target_hard =
                    total_vars - (soft * total_vars as f32).ceil() as usize;
                harden(&mut states, target_hard);
            }
            for _ in 0..tcfg.steps_per_iter {
                t_global += 1;
                let bi = (t_global - 1) as usize;
                let xb = set.batch(bi, batch);
                let per = set.t * set.d * batch;
                let start = (bi % set.n_batches(batch)) * per;
                let yb = Tensor::new(
                    vec![batch, set.t, set.d],
                    y_all.data[start..start + per].to_vec(),
                );
                let loss = par_step(
                    eng, &step_art, &xb, &yb, &consts, &mut states,
                    tcfg.lr, t_global as f32,
                )?;
                if trace.initial_loss.is_nan() {
                    trace.initial_loss = loss;
                }
                if !tcfg.enable_dst {
                    for s in states.values_mut() {
                        s.v = Tensor::zeros(&s.v.shape);
                        s.m_v = Tensor::zeros(&s.v.shape);
                        s.u_v = Tensor::zeros(&s.v.shape);
                    }
                }
                trace.losses.push(loss);
            }
        }

        // final hard merge + stats
        let mut qblock: BTreeMap<String, (Vec<u16>, QParams)> = BTreeMap::new();
        for name in LINEAR_NAMES {
            let s = &states[name];
            let w_orig = &bw.linears[name];
            trace.flips.insert(
                name.to_string(),
                (quant::count_flips(w_orig, &s.nu, &s.qp), s.nu.data.len()),
            );
            let codes = hard_codes(&s.wf, &s.nu, &s.qp, qmax_w);
            let qp_eff = if tcfg.enable_dst {
                dst_effective_scale(&s.qp, &s.v)
            } else {
                s.qp.clone()
            };
            let wq = dequant_codes(&codes, s.o, s.i, &qp_eff);
            params.set_block_linear(l, name, &wq);
            qblock.insert(name.to_string(), (codes, qp_eff));
        }
        per_block.push(trace);
        quantized.push(qblock);

        // propagate the stream through the merged quantized block
        let bw_q = params.block(l);
        let prop_qmax = if tcfg.propagate_act_quant { qmax_act } else { quant::A16_SENTINEL };
        set.x = runner.forward_all(&bw_q, &set, prop_qmax)?;
    }

    Ok(CalibReport { per_block, quantized, wall_s: t0.elapsed().as_secs_f64() })
}

/// Harden phase: pool HS(nu) = |sigmoid(nu) - 0.5| across all linears of
/// the block, saturate the `target_hard` lowest-scoring variables and
/// reset their Adam state.
fn harden(states: &mut BTreeMap<String, LinearState>, target_hard: usize) {
    let total: usize = states.values().map(|s| s.nu.data.len()).sum();
    let already: usize = states
        .values()
        .map(|s| s.nu.data.iter().filter(|x| x.abs() >= SAT_NU).count())
        .sum();
    let target = target_hard.min(total);
    if target <= already {
        return; // cumulative target: never un-harden
    }
    let need = target - already;
    // scores of SOFT variables only, pooled across the block's linears
    let mut scores: Vec<f32> = Vec::with_capacity(total - already);
    for s in states.values() {
        scores.extend(
            s.nu
                .data
                .iter()
                .filter(|x| x.abs() < SAT_NU)
                .map(|&x| (quant::sigmoid(x) - 0.5).abs()),
        );
    }
    let thr = if need >= scores.len() {
        f32::INFINITY
    } else {
        let (_, nth, _) =
            scores.select_nth_unstable_by(need - 1, |a, b| a.partial_cmp(b).unwrap());
        *nth
    };
    let mut hardened = 0usize;
    for s in states.values_mut() {
        for idx in 0..s.nu.data.len() {
            let x = s.nu.data[idx];
            if x.abs() >= SAT_NU {
                continue;
            }
            let score = (quant::sigmoid(x) - 0.5).abs();
            // tie-break: stop once the quota is filled
            if score < thr || (score == thr && hardened < need) {
                s.nu.data[idx] = if x > 0.0 { SAT_NU } else { -SAT_NU };
                s.m_nu.data[idx] = 0.0;
                s.u_nu.data[idx] = 0.0;
                hardened += 1;
            }
        }
    }
}

/// Device-resident per-block constants (perf: §Perf L3 — uploading the
/// weight grid and scales once per block instead of per step removes
/// ~40% of the per-step host->device traffic; see benches/calib_step).
struct BlockConstBufs {
    norm1: xla::PjRtBuffer,
    norm2: xla::PjRtBuffer,
    /// (wf, s, z) per linear in LINEAR_NAMES order
    per_linear: Vec<[xla::PjRtBuffer; 3]>,
    qmax_w: xla::PjRtBuffer,
    qmax_act: xla::PjRtBuffer,
}

impl BlockConstBufs {
    fn new(
        eng: &Engine,
        norm1: &Tensor,
        norm2: &Tensor,
        states: &BTreeMap<String, LinearState>,
        qmax_w: f32,
        qmax_act: f32,
    ) -> Result<Self> {
        let per_linear = LINEAR_NAMES
            .iter()
            .map(|name| {
                let s = &states[*name];
                Ok([
                    eng.upload(&s.wf)?,
                    eng.upload(&s.qp.s)?,
                    eng.upload(&s.qp.z)?,
                ])
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(BlockConstBufs {
            norm1: eng.upload(norm1)?,
            norm2: eng.upload(norm2)?,
            per_linear,
            qmax_w: eng.upload_scalar(qmax_w)?,
            qmax_act: eng.upload_scalar(qmax_act)?,
        })
    }
}

/// One soften-phase Adam step through the artifact; returns the loss and
/// updates all host-side state in place.
#[allow(clippy::too_many_arguments)]
fn par_step(
    eng: &Engine,
    art: &crate::runtime::Artifact,
    x: &Tensor,
    y: &Tensor,
    consts: &BlockConstBufs,
    states: &mut BTreeMap<String, LinearState>,
    lr: f32,
    t: f32,
) -> Result<f32> {
    // mutable state uploads (fresh every step)
    let xb = eng.upload(x)?;
    let yb = eng.upload(y)?;
    let mut var_bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(6 * LINEAR_NAMES.len());
    for field in ["nu", "v", "m_nu", "u_nu", "m_v", "u_v"] {
        for name in LINEAR_NAMES {
            let s = &states[name];
            let t = match field {
                "nu" => &s.nu,
                "v" => &s.v,
                "m_nu" => &s.m_nu,
                "u_nu" => &s.u_nu,
                "m_v" => &s.m_v,
                _ => &s.u_v,
            };
            var_bufs.push(eng.upload(t)?);
        }
    }
    let lr_b = eng.upload_scalar(lr)?;
    let t_b = eng.upload_scalar(t)?;

    let mut bufs: Vec<&xla::PjRtBuffer> = vec![&xb, &yb, &consts.norm1, &consts.norm2];
    for triple in &consts.per_linear {
        bufs.extend([&triple[0], &triple[1], &triple[2]]);
    }
    bufs.extend(var_bufs.iter());
    bufs.push(&lr_b);
    bufs.push(&t_b);
    bufs.push(&consts.qmax_w);
    bufs.push(&consts.qmax_act);

    let outs = eng.run_buffers(art, &bufs)?;
    let loss = outs[0].data[0];
    let n = LINEAR_NAMES.len();
    for (fi, field) in ["nu", "v", "m_nu", "u_nu", "m_v", "u_v"].iter().enumerate() {
        for (li, name) in LINEAR_NAMES.iter().enumerate() {
            let t = outs[1 + fi * n + li].clone();
            let s = states.get_mut(*name).unwrap();
            match *field {
                "nu" => s.nu = t,
                "v" => s.v = t,
                "m_nu" => s.m_nu = t,
                "u_nu" => s.u_nu = t,
                "m_v" => s.m_v = t,
                _ => s.u_v = t,
            }
        }
    }
    Ok(loss)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harden_saturates_lowest_scores() {
        let mut states = BTreeMap::new();
        let w = Tensor::from_fn(&[2, 8], |i| (i as f32 - 8.0) * 0.13 + 0.01);
        let qp = minmax_scale(&w, 8, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), 3.0);
        states.insert("q_proj".to_string(), LinearState::init(&w, qp, false));
        let before_hard: usize = states["q_proj"]
            .nu
            .data
            .iter()
            .filter(|x| x.abs() >= SAT_NU)
            .count();
        assert_eq!(before_hard, 0);
        harden(&mut states, 10);
        let after: usize = states["q_proj"]
            .nu
            .data
            .iter()
            .filter(|x| x.abs() >= SAT_NU)
            .count();
        assert!(after >= 10, "hardened {after} < 10");
        // monotone: hardening to a smaller target is a no-op
        harden(&mut states, 5);
        let after2: usize = states["q_proj"]
            .nu
            .data
            .iter()
            .filter(|x| x.abs() >= SAT_NU)
            .count();
        assert_eq!(after, after2);
    }

    #[test]
    fn hardened_start_is_rtn() {
        let w = Tensor::from_fn(&[2, 8], |i| i as f32 * 0.37 - 1.0);
        let qp = minmax_scale(&w, 8, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), 3.0);
        let st = LinearState::init(&w, qp.clone(), true);
        assert!(st.nu.data.iter().all(|x| x.abs() >= SAT_NU));
        // hard codes == RTN codes
        let hard = hard_codes(&st.wf, &st.nu, &qp, 3.0);
        let rtn = quant::rtn_codes(&w, &qp, 3.0);
        assert_eq!(hard, rtn);
    }
}
