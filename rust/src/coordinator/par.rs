//! TesseraQ calibration: Progressive Adaptive Rounding + Dequantization
//! Scale Tuning over block-wise reconstruction (paper Algorithm 1).
//!
//! Host side owns the PAR state (nu, v, Adam moments) and the harden
//! phase (HS scoring + saturation at +-SAT_NU); each soften-phase step
//! executes the AOT `block_par_step` artifact. Hardened logits receive
//! exactly-zero gradients inside the artifact, so no masking is needed —
//! the paper's memory-efficient trick.
//!
//! Resilience (`calibrate_tesseraq_robust`): each completed block is
//! persisted to a checksummed checkpoint so a killed run resumes from the
//! first incomplete block; numerical sentinels roll the soften loop back
//! to the last iteration-start snapshot on NaN/Inf/divergence and retry
//! with a backed-off learning rate before degrading the block to hardened
//! RTN; artifact compile/execute failures retry with exponential backoff
//! and then fall back to the host-side reference forward. Every recovery
//! path warns instead of crashing.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use crate::coordinator::pipeline::{CalibSet, ForwardBackend};
use crate::coordinator::schedule::Schedule;
use crate::model::{BlockView, Params, LINEAR_NAMES};
use crate::quant::{
    self, dequant_codes, dst_effective_scale, hard_codes, minmax_scale, nu_init,
    w_floor, ClipFactors, QParams, QuantConfig, SAT_NU,
};
use crate::robust::checkpoint::fnv1a64;
use crate::robust::{
    with_retry, BlockCheckpoint, CheckpointStore, LossHealth, RobustConfig, Sentinel,
    KILL_MARKER,
};
use crate::runtime::{Artifact, Engine};
use crate::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct TesseraqConfig {
    pub qcfg: QuantConfig,
    /// PAR iterations (paper K = 20; scaled down for the tiny testbed).
    pub iterations: usize,
    /// Soften-phase Adam steps per iteration (paper T = 250).
    pub steps_per_iter: usize,
    pub lr: f32,
    pub schedule: Schedule,
    /// Ablation switches (Table 6).
    pub enable_par: bool,
    pub enable_dst: bool,
    /// Quantize the propagated stream with the target act bits.
    pub propagate_act_quant: bool,
    /// Artifact name suffix selecting a batch-size variant (Table 5),
    /// e.g. ".b1" -> block_par_step.<size>.<scheme>.b1.
    pub artifact_suffix: String,
}

impl TesseraqConfig {
    pub fn standard(qcfg: QuantConfig) -> Self {
        TesseraqConfig {
            qcfg,
            iterations: 8,
            steps_per_iter: 24,
            lr: 1e-2,
            schedule: Schedule::Handcrafted,
            enable_par: true,
            enable_dst: true,
            propagate_act_quant: false,
            artifact_suffix: String::new(),
        }
    }

    /// Fast preset for tests/CI.
    pub fn fast(qcfg: QuantConfig) -> Self {
        TesseraqConfig { iterations: 4, steps_per_iter: 8, ..Self::standard(qcfg) }
    }
}

/// How a block's final codes were produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockStatus {
    /// Full PAR/DST optimization ran to completion.
    Optimized,
    /// The resilience layer degraded this block to hardened RTN (sentinel
    /// retry budget exhausted, or no PAR step path available).
    RtnFallback,
}

/// Per-block calibration record (Fig. 4 traces + Table 7 flip stats).
#[derive(Debug, Clone, PartialEq)]
pub struct BlockTrace {
    pub layer: usize,
    /// reconstruction MSE after each soften step
    pub losses: Vec<f32>,
    /// per linear: (flipped vs RTN, total rounding variables)
    pub flips: BTreeMap<String, (usize, usize)>,
    /// loss right before any optimization (RTN-equivalent start)
    pub initial_loss: f32,
    pub status: BlockStatus,
}

pub struct CalibReport {
    pub per_block: Vec<BlockTrace>,
    /// per block, per linear: final integer codes + effective dequant
    /// params (s_eff = 2*sigmoid(v)*s) — ready for packing/serving.
    pub quantized: Vec<BTreeMap<String, (Vec<u16>, QParams)>>,
    pub wall_s: f64,
}

impl CalibReport {
    /// Blocks the resilience layer degraded to RTN.
    pub fn fallback_blocks(&self) -> Vec<usize> {
        self.per_block
            .iter()
            .filter(|t| t.status == BlockStatus::RtnFallback)
            .map(|t| t.layer)
            .collect()
    }
}

struct LinearState {
    qp: QParams,
    wf: Tensor,
    nu: Tensor,
    v: Tensor,
    m_nu: Tensor,
    u_nu: Tensor,
    m_v: Tensor,
    u_v: Tensor,
}

impl LinearState {
    fn init(w: &Tensor, qp: QParams, hardened_start: bool) -> LinearState {
        let wf = w_floor(w, &qp);
        let mut nu = nu_init(w, &qp);
        if hardened_start {
            for x in nu.data.iter_mut() {
                *x = if *x > 0.0 { SAT_NU } else { -SAT_NU };
            }
        }
        let gshape = qp.s.shape.clone();
        LinearState {
            wf,
            nu: nu.clone(),
            v: Tensor::zeros(&gshape),
            m_nu: Tensor::zeros(&nu.shape),
            u_nu: Tensor::zeros(&nu.shape),
            m_v: Tensor::zeros(&gshape),
            u_v: Tensor::zeros(&gshape),
            qp,
        }
    }
}

/// Optional per-linear clip factors from an initializer (AWQ / LWC).
pub type BlockClips = BTreeMap<String, (Tensor, Tensor)>;

/// Run TesseraQ over the whole model in place. `clips[l]` supplies the
/// (gamma, beta) per-group clip factors from the initializer (None ->
/// plain min/max). Weights in `params` must already carry any scale
/// transformation (AWQ fold) — exactly the paper's Fig. 1(a) flow.
///
/// Thin wrapper over [`calibrate_tesseraq_robust`] with the default
/// resilience knobs (sentinels + retries on, no checkpointing).
pub fn calibrate_tesseraq(
    eng: &Engine,
    params: &mut Params,
    clips: Option<&[BlockClips]>,
    tokens: &[i32],
    n_seq: usize,
    tcfg: &TesseraqConfig,
) -> Result<CalibReport> {
    calibrate_tesseraq_robust(
        Some(eng), params, clips, tokens, n_seq, tcfg, &RobustConfig::default(),
    )
}

/// Fault-tolerant TesseraQ calibration. `eng = None` runs entirely on the
/// host-forward path (every block degrades to hardened RTN — no PAR step
/// artifact), which is also what a run with a persistently failing device
/// converges to.
pub fn calibrate_tesseraq_robust(
    eng: Option<&Engine>,
    params: &mut Params,
    clips: Option<&[BlockClips]>,
    tokens: &[i32],
    n_seq: usize,
    tcfg: &TesseraqConfig,
    robust: &RobustConfig,
) -> Result<CalibReport> {
    let t0 = std::time::Instant::now();
    let size = params.cfg.name.clone();
    let scheme = tcfg.qcfg.scheme.tag();
    if let (Some(e), Some(plan)) = (eng, &robust.faults) {
        e.set_fault_plan(Some(plan.clone()));
    }

    let backend = ForwardBackend::new(eng, &params.cfg, &size, &robust.retry);

    // PAR soften-step artifact; unavailable -> hardened RTN per block.
    let step_art = eng.and_then(|e| {
        let name = format!("block_par_step.{size}.{scheme}{}", tcfg.artifact_suffix);
        match with_retry(&robust.retry, &format!("compiling {name}"), || e.artifact(&name)) {
            Ok(a) => Some(a),
            Err(err) => {
                eprintln!(
                    "[robust] PAR step artifact unavailable; \
                     degrading to hardened RTN per block: {err:#}"
                );
                None
            }
        }
    });
    let batch = step_art.as_ref().map_or(1, |a| a.spec.meta.batch.unwrap_or(4));
    if step_art.is_some() {
        ensure!(n_seq % batch == 0, "n_seq {n_seq} not divisible by batch {batch}");
    }

    let qmax_w = tcfg.qcfg.qmax_w();
    let qmax_act = tcfg.qcfg.qmax_act();
    let n_layers = params.cfg.n_layers;

    // Checkpoint store; resume restores the valid contiguous prefix.
    let fingerprint = config_fingerprint(params, tcfg, tokens, n_seq);
    let store = match &robust.checkpoint_dir {
        Some(dir) => Some(CheckpointStore::new(dir, fingerprint)?),
        None => None,
    };
    let mut per_block: Vec<BlockTrace> = Vec::new();
    let mut quantized: Vec<BTreeMap<String, (Vec<u16>, QParams)>> = Vec::new();
    if let Some(store) = &store {
        if robust.resume {
            for ckpt in store.load_prefix(n_layers) {
                merge_block(params, ckpt.trace.layer, &ckpt.quantized);
                per_block.push(ckpt.trace);
                quantized.push(ckpt.quantized);
            }
            if !per_block.is_empty() {
                eprintln!(
                    "[robust] resuming: {}/{} blocks restored from {}",
                    per_block.len(),
                    n_layers,
                    store.dir().display()
                );
            }
        } else {
            store.clear()?;
        }
    }
    let start_block = per_block.len();

    let mut set = CalibSet::from_tokens(params, tokens, n_seq);
    let prop_qmax = if tcfg.propagate_act_quant { qmax_act } else { quant::A16_SENTINEL };
    // Rebuild the residual stream through the restored (already merged)
    // prefix — the same f32 ops as the original pass, so a resumed run
    // reproduces the interrupted run bit for bit.
    for l in 0..start_block {
        let bw_q = params.block(l);
        set.x = backend.forward_all(&bw_q, &set, prop_qmax)?;
    }

    for l in start_block..n_layers {
        let (trace, qblock) = calibrate_block(
            eng,
            step_art.as_deref(),
            &backend,
            params,
            clips,
            &set,
            l,
            batch,
            tcfg,
            robust,
            qmax_w,
            qmax_act,
        )?;
        merge_block(params, l, &qblock);
        if let Some(store) = &store {
            store.save_block(
                l,
                &BlockCheckpoint { trace: trace.clone(), quantized: qblock.clone() },
            )?;
        }
        per_block.push(trace);
        quantized.push(qblock);
        if robust.faults.as_ref().is_some_and(|f| f.kill_after_block(l)) {
            bail!("{KILL_MARKER} after block {l}");
        }
        // propagate the stream through the merged quantized block
        let bw_q = params.block(l);
        set.x = backend.forward_all(&bw_q, &set, prop_qmax)?;
    }

    Ok(CalibReport { per_block, quantized, wall_s: t0.elapsed().as_secs_f64() })
}

/// Hash of everything that determines a calibration run's outputs: the
/// checkpoint format version, model/quant/schedule configuration, the
/// calibration tokens, and the (embedding) weights. Stored in every block
/// checkpoint; a mismatch refuses resume.
fn config_fingerprint(
    params: &Params,
    tcfg: &TesseraqConfig,
    tokens: &[i32],
    n_seq: usize,
) -> u64 {
    let mut bytes = format!(
        "v{};model={};quant={};iters={};steps={};lr={};schedule={:?};par={};dst={};prop={};suffix={};n_seq={}",
        crate::robust::checkpoint::VERSION,
        params.cfg.name,
        tcfg.qcfg.label(),
        tcfg.iterations,
        tcfg.steps_per_iter,
        tcfg.lr,
        tcfg.schedule,
        tcfg.enable_par,
        tcfg.enable_dst,
        tcfg.propagate_act_quant,
        tcfg.artifact_suffix,
        n_seq,
    )
    .into_bytes();
    for &t in tokens {
        bytes.extend_from_slice(&t.to_le_bytes());
    }
    // cheap weight identity: the embedding table's raw bits
    for &v in &params.get("emb").data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    fnv1a64(&bytes)
}

/// Merge one block's final codes into the model (fake-quant weights).
fn merge_block(
    params: &mut Params,
    layer: usize,
    qblock: &BTreeMap<String, (Vec<u16>, QParams)>,
) {
    for (name, (codes, qp)) in qblock {
        let o = qp.s.shape[0];
        let i = codes.len() / o;
        let wq = dequant_codes(codes, o, i, qp);
        params.set_block_linear(layer, name, &wq);
    }
}

fn init_states(
    bw: &BlockView,
    clips: Option<&[BlockClips]>,
    l: usize,
    tcfg: &TesseraqConfig,
    qmax_w: f32,
) -> BTreeMap<String, LinearState> {
    let mut states = BTreeMap::new();
    for name in LINEAR_NAMES {
        let w = &bw.linears[name];
        let g = tcfg.qcfg.scheme.group_size(w.shape[1]);
        let qp = match clips.and_then(|c| c[l].get(name)) {
            Some((gm, bt)) => minmax_scale(
                w,
                g,
                &ClipFactors::PerGroup(gm.clone()),
                &ClipFactors::PerGroup(bt.clone()),
                qmax_w,
            ),
            None => minmax_scale(
                w,
                g,
                &ClipFactors::Uniform(1.0),
                &ClipFactors::Uniform(1.0),
                qmax_w,
            ),
        };
        states.insert(name.to_string(), LinearState::init(w, qp, !tcfg.enable_par));
    }
    states
}

/// Calibrate one block: PAR/DST when the device path is up, hardened RTN
/// otherwise. Returns the block trace and the final (codes, QParams) map;
/// the caller merges them into the model.
fn calibrate_block(
    eng: Option<&Engine>,
    step_art: Option<&Artifact>,
    backend: &ForwardBackend,
    params: &Params,
    clips: Option<&[BlockClips]>,
    set: &CalibSet,
    l: usize,
    batch: usize,
    tcfg: &TesseraqConfig,
    robust: &RobustConfig,
    qmax_w: f32,
    qmax_act: f32,
) -> Result<(BlockTrace, BTreeMap<String, (Vec<u16>, QParams)>)> {
    let bw = params.block(l);
    let mut states = init_states(&bw, clips, l, tcfg, qmax_w);
    let mut trace = BlockTrace {
        layer: l,
        losses: Vec::new(),
        flips: BTreeMap::new(),
        initial_loss: f32::NAN,
        status: BlockStatus::Optimized,
    };

    let mut fallback_reason: Option<String> = None;
    match (eng, step_art) {
        (Some(e), Some(art)) => {
            match run_par_loop(
                e, art, backend, &bw, set, l, batch, tcfg, robust, &mut states, &mut trace,
                qmax_w, qmax_act,
            )? {
                ParOutcome::Done => {}
                ParOutcome::Fallback(reason) => fallback_reason = Some(reason),
            }
        }
        _ => fallback_reason = Some("no PAR step path available".to_string()),
    }

    let mut qblock = BTreeMap::new();
    if let Some(reason) = fallback_reason {
        eprintln!("[robust] block {l}: hardened-RTN fallback ({reason})");
        trace.losses.clear();
        trace.initial_loss = 0.0;
        trace.status = BlockStatus::RtnFallback;
        for name in LINEAR_NAMES {
            let s = &states[name];
            let w = &bw.linears[name];
            let codes = quant::rtn_codes(w, &s.qp, qmax_w);
            trace.flips.insert(name.to_string(), (0, codes.len()));
            qblock.insert(name.to_string(), (codes, s.qp.clone()));
        }
    } else {
        for name in LINEAR_NAMES {
            let s = &states[name];
            let w_orig = &bw.linears[name];
            trace.flips.insert(
                name.to_string(),
                (quant::count_flips(w_orig, &s.nu, &s.qp), s.nu.data.len()),
            );
            let codes = hard_codes(&s.wf, &s.nu, &s.qp, qmax_w);
            let qp_eff = if tcfg.enable_dst {
                dst_effective_scale(&s.qp, &s.v)
            } else {
                s.qp.clone()
            };
            qblock.insert(name.to_string(), (codes, qp_eff));
        }
    }
    Ok((trace, qblock))
}

enum ParOutcome {
    Done,
    /// Degrade this block to hardened RTN, with the reason for the log.
    Fallback(String),
}

enum StepFailure {
    /// Device execution kept failing after retries — not recoverable by
    /// rollback, degrade the block.
    Exec(String),
    /// NaN/Inf/diverged loss — recoverable by rollback + LR backoff.
    Numeric(String),
}

/// Iteration-start snapshot of everything `par_step` mutates, so a bad
/// iteration can be rolled back exactly (including Adam time `t_global`
/// and the batch index derived from it).
struct ParSnapshot {
    fields: BTreeMap<String, [Tensor; 6]>,
    t_global: u32,
    n_losses: usize,
    initial_loss: f32,
}

impl ParSnapshot {
    fn take(
        states: &BTreeMap<String, LinearState>,
        t_global: u32,
        trace: &BlockTrace,
    ) -> ParSnapshot {
        let fields = states
            .iter()
            .map(|(k, s)| {
                (
                    k.clone(),
                    [
                        s.nu.clone(),
                        s.v.clone(),
                        s.m_nu.clone(),
                        s.u_nu.clone(),
                        s.m_v.clone(),
                        s.u_v.clone(),
                    ],
                )
            })
            .collect();
        ParSnapshot {
            fields,
            t_global,
            n_losses: trace.losses.len(),
            initial_loss: trace.initial_loss,
        }
    }

    fn restore(
        &self,
        states: &mut BTreeMap<String, LinearState>,
        t_global: &mut u32,
        trace: &mut BlockTrace,
    ) {
        for (k, f) in &self.fields {
            if let Some(s) = states.get_mut(k) {
                s.nu = f[0].clone();
                s.v = f[1].clone();
                s.m_nu = f[2].clone();
                s.u_nu = f[3].clone();
                s.m_v = f[4].clone();
                s.u_v = f[5].clone();
            }
        }
        *t_global = self.t_global;
        trace.losses.truncate(self.n_losses);
        trace.initial_loss = self.initial_loss;
    }
}

fn run_par_loop(
    eng: &Engine,
    step_art: &Artifact,
    backend: &ForwardBackend,
    bw: &BlockView,
    set: &CalibSet,
    l: usize,
    batch: usize,
    tcfg: &TesseraqConfig,
    robust: &RobustConfig,
    states: &mut BTreeMap<String, LinearState>,
    trace: &mut BlockTrace,
    qmax_w: f32,
    qmax_act: f32,
) -> Result<ParOutcome> {
    // teacher target on the (quantized-prefix) stream, FP weights
    let y_all = backend.forward_all(bw, set, quant::A16_SENTINEL)?;

    // per-block constants live on device for the whole PAR loop
    let consts = match BlockConstBufs::new(eng, &bw.norm1, &bw.norm2, states, qmax_w, qmax_act)
    {
        Ok(c) => c,
        Err(e) => return Ok(ParOutcome::Fallback(format!("uploading block constants: {e:#}"))),
    };

    let mut sentinel = Sentinel::new(robust.sentinel);
    let mut t_global = 0u32;
    let mut k = 1;
    while k <= tcfg.iterations {
        let snap = ParSnapshot::take(states, t_global, trace);
        if tcfg.enable_par {
            let total_vars: usize = states.values().map(|s| s.nu.data.len()).sum();
            let soft = tcfg.schedule.soft_rate(k, tcfg.iterations);
            let target_hard = total_vars - (soft * total_vars as f32).ceil() as usize;
            harden(states, target_hard);
        }
        let mut failure: Option<StepFailure> = None;
        for _ in 0..tcfg.steps_per_iter {
            t_global += 1;
            let bi = (t_global - 1) as usize;
            let xb = set.batch(bi, batch);
            let per = set.t * set.d * batch;
            let start = (bi % set.n_batches(batch)) * per;
            let yb = Tensor::new(
                vec![batch, set.t, set.d],
                y_all.data[start..start + per].to_vec(),
            );
            let lr = tcfg.lr * sentinel.lr_scale;
            let step_res = with_retry(&robust.retry, "PAR step", || {
                par_step(eng, step_art, &xb, &yb, &consts, states, lr, t_global as f32)
            });
            let mut loss = match step_res {
                Ok(loss) => loss,
                Err(e) => {
                    failure = Some(StepFailure::Exec(format!("{e:#}")));
                    break;
                }
            };
            if robust.faults.as_ref().is_some_and(|f| f.nan_loss(l, t_global as usize)) {
                loss = f32::NAN;
            }
            match sentinel.observe(loss) {
                LossHealth::Ok => {
                    if trace.initial_loss.is_nan() {
                        trace.initial_loss = loss;
                    }
                    if !tcfg.enable_dst {
                        for s in states.values_mut() {
                            s.v = Tensor::zeros(&s.v.shape);
                            s.m_v = Tensor::zeros(&s.v.shape);
                            s.u_v = Tensor::zeros(&s.v.shape);
                        }
                    }
                    trace.losses.push(loss);
                }
                LossHealth::NonFinite => {
                    failure = Some(StepFailure::Numeric(format!("non-finite loss {loss}")));
                    break;
                }
                LossHealth::Diverged { baseline } => {
                    failure = Some(StepFailure::Numeric(format!(
                        "loss {loss:.3e} diverged (baseline {baseline:.3e})"
                    )));
                    break;
                }
            }
        }
        match failure {
            None => k += 1,
            Some(StepFailure::Exec(reason)) => {
                return Ok(ParOutcome::Fallback(format!("PAR step execution: {reason}")));
            }
            Some(StepFailure::Numeric(reason)) => match sentinel.trip() {
                Some(scale) => {
                    eprintln!(
                        "[robust] block {l} iteration {k}: {reason}; rolling back to the \
                         iteration-start snapshot, retrying with lr scale {scale}"
                    );
                    snap.restore(states, &mut t_global, trace);
                }
                None => {
                    return Ok(ParOutcome::Fallback(format!(
                        "{reason} after {} rollbacks",
                        sentinel.retries_used()
                    )));
                }
            },
        }
    }
    Ok(ParOutcome::Done)
}

/// Harden phase: pool HS(nu) = |sigmoid(nu) - 0.5| across all linears of
/// the block, saturate the `target_hard` lowest-scoring variables and
/// reset their Adam state.
fn harden(states: &mut BTreeMap<String, LinearState>, target_hard: usize) {
    let total: usize = states.values().map(|s| s.nu.data.len()).sum();
    let already: usize = states
        .values()
        .map(|s| s.nu.data.iter().filter(|x| x.abs() >= SAT_NU).count())
        .sum();
    let target = target_hard.min(total);
    if target <= already {
        return; // cumulative target: never un-harden
    }
    let need = target - already;
    // scores of SOFT variables only, pooled across the block's linears
    let mut scores: Vec<f32> = Vec::with_capacity(total - already);
    for s in states.values() {
        scores.extend(
            s.nu
                .data
                .iter()
                .filter(|x| x.abs() < SAT_NU)
                .map(|&x| (quant::sigmoid(x) - 0.5).abs()),
        );
    }
    let thr = if need >= scores.len() {
        f32::INFINITY
    } else {
        let (_, nth, _) =
            scores.select_nth_unstable_by(need - 1, |a, b| a.total_cmp(b));
        *nth
    };
    let mut hardened = 0usize;
    for s in states.values_mut() {
        for idx in 0..s.nu.data.len() {
            let x = s.nu.data[idx];
            if x.abs() >= SAT_NU {
                continue;
            }
            let score = (quant::sigmoid(x) - 0.5).abs();
            // tie-break: stop once the quota is filled
            if score < thr || (score == thr && hardened < need) {
                s.nu.data[idx] = if x > 0.0 { SAT_NU } else { -SAT_NU };
                s.m_nu.data[idx] = 0.0;
                s.u_nu.data[idx] = 0.0;
                hardened += 1;
            }
        }
    }
}

/// Device-resident per-block constants (perf: §Perf L3 — uploading the
/// weight grid and scales once per block instead of per step removes
/// ~40% of the per-step host->device traffic; see benches/calib_step).
struct BlockConstBufs {
    norm1: xla::PjRtBuffer,
    norm2: xla::PjRtBuffer,
    /// (wf, s, z) per linear in LINEAR_NAMES order
    per_linear: Vec<[xla::PjRtBuffer; 3]>,
    qmax_w: xla::PjRtBuffer,
    qmax_act: xla::PjRtBuffer,
}

impl BlockConstBufs {
    fn new(
        eng: &Engine,
        norm1: &Tensor,
        norm2: &Tensor,
        states: &BTreeMap<String, LinearState>,
        qmax_w: f32,
        qmax_act: f32,
    ) -> Result<Self> {
        let per_linear = LINEAR_NAMES
            .iter()
            .map(|name| {
                let s = &states[*name];
                Ok([
                    eng.upload(&s.wf)?,
                    eng.upload(&s.qp.s)?,
                    eng.upload(&s.qp.z)?,
                ])
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(BlockConstBufs {
            norm1: eng.upload(norm1)?,
            norm2: eng.upload(norm2)?,
            per_linear,
            qmax_w: eng.upload_scalar(qmax_w)?,
            qmax_act: eng.upload_scalar(qmax_act)?,
        })
    }
}

/// One soften-phase Adam step through the artifact; returns the loss and
/// updates all host-side state in place.
fn par_step(
    eng: &Engine,
    art: &Artifact,
    x: &Tensor,
    y: &Tensor,
    consts: &BlockConstBufs,
    states: &mut BTreeMap<String, LinearState>,
    lr: f32,
    t: f32,
) -> Result<f32> {
    // mutable state uploads (fresh every step)
    let xb = eng.upload(x)?;
    let yb = eng.upload(y)?;
    let mut var_bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(6 * LINEAR_NAMES.len());
    for field in ["nu", "v", "m_nu", "u_nu", "m_v", "u_v"] {
        for name in LINEAR_NAMES {
            let s = &states[name];
            let t = match field {
                "nu" => &s.nu,
                "v" => &s.v,
                "m_nu" => &s.m_nu,
                "u_nu" => &s.u_nu,
                "m_v" => &s.m_v,
                _ => &s.u_v,
            };
            var_bufs.push(eng.upload(t)?);
        }
    }
    let lr_b = eng.upload_scalar(lr)?;
    let t_b = eng.upload_scalar(t)?;

    let mut bufs: Vec<&xla::PjRtBuffer> = vec![&xb, &yb, &consts.norm1, &consts.norm2];
    for triple in &consts.per_linear {
        bufs.extend([&triple[0], &triple[1], &triple[2]]);
    }
    bufs.extend(var_bufs.iter());
    bufs.push(&lr_b);
    bufs.push(&t_b);
    bufs.push(&consts.qmax_w);
    bufs.push(&consts.qmax_act);

    let outs = eng.run_buffers(art, &bufs)?;
    let loss = outs[0].data[0];
    let n = LINEAR_NAMES.len();
    for (fi, field) in ["nu", "v", "m_nu", "u_nu", "m_v", "u_v"].iter().enumerate() {
        for (li, name) in LINEAR_NAMES.iter().enumerate() {
            let t = outs[1 + fi * n + li].clone();
            let s = states.get_mut(*name).expect("state exists for every linear name");
            match *field {
                "nu" => s.nu = t,
                "v" => s.v = t,
                "m_nu" => s.m_nu = t,
                "u_nu" => s.u_nu = t,
                "m_v" => s.m_v = t,
                _ => s.u_v = t,
            }
        }
    }
    Ok(loss)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harden_saturates_lowest_scores() {
        let mut states = BTreeMap::new();
        let w = Tensor::from_fn(&[2, 8], |i| (i as f32 - 8.0) * 0.13 + 0.01);
        let qp = minmax_scale(&w, 8, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), 3.0);
        states.insert("q_proj".to_string(), LinearState::init(&w, qp, false));
        let before_hard: usize = states["q_proj"]
            .nu
            .data
            .iter()
            .filter(|x| x.abs() >= SAT_NU)
            .count();
        assert_eq!(before_hard, 0);
        harden(&mut states, 10);
        let after: usize = states["q_proj"]
            .nu
            .data
            .iter()
            .filter(|x| x.abs() >= SAT_NU)
            .count();
        assert!(after >= 10, "hardened {after} < 10");
        // monotone: hardening to a smaller target is a no-op
        harden(&mut states, 5);
        let after2: usize = states["q_proj"]
            .nu
            .data
            .iter()
            .filter(|x| x.abs() >= SAT_NU)
            .count();
        assert_eq!(after, after2);
    }

    #[test]
    fn hardened_start_is_rtn() {
        let w = Tensor::from_fn(&[2, 8], |i| i as f32 * 0.37 - 1.0);
        let qp = minmax_scale(&w, 8, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), 3.0);
        let st = LinearState::init(&w, qp.clone(), true);
        assert!(st.nu.data.iter().all(|x| x.abs() >= SAT_NU));
        // hard codes == RTN codes
        let hard = hard_codes(&st.wf, &st.nu, &qp, 3.0);
        let rtn = quant::rtn_codes(&w, &qp, 3.0);
        assert_eq!(hard, rtn);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let w = Tensor::from_fn(&[2, 8], |i| i as f32 * 0.21 - 1.3);
        let qp = minmax_scale(&w, 8, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), 3.0);
        let mut states = BTreeMap::new();
        states.insert("q_proj".to_string(), LinearState::init(&w, qp, false));
        let mut trace = BlockTrace {
            layer: 0,
            losses: vec![1.0, 0.5],
            flips: BTreeMap::new(),
            initial_loss: 1.0,
            status: BlockStatus::Optimized,
        };
        let mut t_global = 7u32;
        let snap = ParSnapshot::take(&states, t_global, &trace);
        // corrupt everything the soften loop mutates
        for s in states.values_mut() {
            for x in s.nu.data.iter_mut() {
                *x = f32::NAN;
            }
            s.m_nu = Tensor::full(&s.m_nu.shape, 9.0);
        }
        trace.losses.push(f32::NAN);
        trace.initial_loss = f32::NAN;
        t_global = 99;
        snap.restore(&mut states, &mut t_global, &mut trace);
        assert_eq!(t_global, 7);
        assert_eq!(trace.losses, vec![1.0, 0.5]);
        assert_eq!(trace.initial_loss, 1.0);
        assert!(states["q_proj"].nu.data.iter().all(|x| x.is_finite()));
        assert!(states["q_proj"].m_nu.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn fingerprint_tracks_config_and_data() {
        let cfg = crate::model::ModelConfig::preset("nano").unwrap();
        let mut rng = crate::tensor::Pcg32::seeded(0);
        let p = Params::init(&cfg, &mut rng);
        let qcfg = QuantConfig::weight_only(2, crate::quant::GroupScheme::Group(32));
        let tcfg = TesseraqConfig::fast(qcfg);
        let tokens: Vec<i32> = (0..64).map(|i| i % 100).collect();
        let a = config_fingerprint(&p, &tcfg, &tokens, 4);
        assert_eq!(a, config_fingerprint(&p, &tcfg, &tokens, 4), "deterministic");
        let mut t2 = tcfg.clone();
        t2.lr *= 2.0;
        assert_ne!(a, config_fingerprint(&p, &t2, &tokens, 4), "lr changes fingerprint");
        let mut tok2 = tokens.clone();
        tok2[0] += 1;
        assert_ne!(a, config_fingerprint(&p, &tcfg, &tok2, 4), "tokens change fingerprint");
    }
}
