//! TesseraQ calibration: Progressive Adaptive Rounding + Dequantization
//! Scale Tuning over block-wise reconstruction (paper Algorithm 1).
//!
//! This module owns only the PAR math — the harden phase (HS scoring +
//! saturation at +-SAT_NU), the soften-phase Adam steps through the AOT
//! `block_par_step` artifact, and the final code emission. Everything a
//! reconstruction method shares (teacher targets, checkpoint/resume,
//! stream propagation, fault injection) lives in the unified
//! [`crate::coordinator::driver`]; TesseraQ plugs in as [`ParOptimizer`]
//! and reuses the sentinel rollback loop via the driver's `GuardedIter`.
//!
//! Hardened logits receive exactly-zero gradients inside the artifact, so
//! no masking is needed — the paper's memory-efficient trick.

use std::collections::BTreeMap;
use std::rc::Rc;

use anyhow::{ensure, Result};

pub use crate::coordinator::driver::{BlockStatus, BlockTrace, CalibReport};

use crate::coordinator::driver::{
    run_guarded, BlockCtx, BlockOptimizer, BlockOutcome, GuardedIter, IterFailure,
    ReconstructionDriver,
};
use crate::coordinator::pipeline::CalibSet;
use crate::coordinator::schedule::Schedule;
use crate::model::{BlockView, Params, LINEAR_NAMES};
use crate::obs;
use crate::quant::{
    self, dst_effective_scale, hard_codes, minmax_scale, nu_init, w_floor, ClipFactors,
    QParams, QuantConfig, SAT_NU,
};
use crate::robust::{with_retry, LossHealth, RobustConfig, Sentinel};
use crate::runtime::{Artifact, Engine};
use crate::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct TesseraqConfig {
    pub qcfg: QuantConfig,
    /// PAR iterations (paper K = 20; scaled down for the tiny testbed).
    pub iterations: usize,
    /// Soften-phase Adam steps per iteration (paper T = 250).
    pub steps_per_iter: usize,
    pub lr: f32,
    pub schedule: Schedule,
    /// Ablation switches (Table 6).
    pub enable_par: bool,
    pub enable_dst: bool,
    /// Quantize the propagated stream with the target act bits.
    pub propagate_act_quant: bool,
    /// Artifact name suffix selecting a batch-size variant (Table 5),
    /// e.g. ".b1" -> block_par_step.<size>.<scheme>.b1.
    pub artifact_suffix: String,
}

impl TesseraqConfig {
    pub fn standard(qcfg: QuantConfig) -> Self {
        TesseraqConfig {
            qcfg,
            iterations: 8,
            steps_per_iter: 24,
            lr: 1e-2,
            schedule: Schedule::Handcrafted,
            enable_par: true,
            enable_dst: true,
            propagate_act_quant: false,
            artifact_suffix: String::new(),
        }
    }

    /// Fast preset for tests/CI.
    pub fn fast(qcfg: QuantConfig) -> Self {
        TesseraqConfig { iterations: 4, steps_per_iter: 8, ..Self::standard(qcfg) }
    }
}

struct LinearState {
    qp: QParams,
    wf: Tensor,
    nu: Tensor,
    v: Tensor,
    m_nu: Tensor,
    u_nu: Tensor,
    m_v: Tensor,
    u_v: Tensor,
}

impl LinearState {
    fn init(w: &Tensor, qp: QParams, hardened_start: bool) -> LinearState {
        let wf = w_floor(w, &qp);
        let mut nu = nu_init(w, &qp);
        if hardened_start {
            for x in nu.data.iter_mut() {
                *x = if *x > 0.0 { SAT_NU } else { -SAT_NU };
            }
        }
        let gshape = qp.s.shape.clone();
        LinearState {
            wf,
            nu: nu.clone(),
            v: Tensor::zeros(&gshape),
            m_nu: Tensor::zeros(&nu.shape),
            u_nu: Tensor::zeros(&nu.shape),
            m_v: Tensor::zeros(&gshape),
            u_v: Tensor::zeros(&gshape),
            qp,
        }
    }
}

/// Optional per-linear clip factors from an initializer (AWQ / LWC).
pub type BlockClips = BTreeMap<String, (Tensor, Tensor)>;

/// Run TesseraQ over the whole model in place. `clips[l]` supplies the
/// (gamma, beta) per-group clip factors from the initializer (None ->
/// plain min/max). Weights in `params` must already carry any scale
/// transformation (AWQ fold) — exactly the paper's Fig. 1(a) flow.
///
/// Thin wrapper over [`calibrate_tesseraq_robust`] with the default
/// resilience knobs (sentinels + retries on, no checkpointing).
pub fn calibrate_tesseraq(
    eng: &Engine,
    params: &mut Params,
    clips: Option<&[BlockClips]>,
    tokens: &[i32],
    n_seq: usize,
    tcfg: &TesseraqConfig,
) -> Result<CalibReport> {
    calibrate_tesseraq_robust(
        Some(eng), params, clips, tokens, n_seq, tcfg, &RobustConfig::default(),
    )
}

/// Fault-tolerant TesseraQ calibration through the unified
/// [`ReconstructionDriver`]. `eng = None` runs entirely on the
/// host-forward path (every block degrades to hardened RTN — no PAR step
/// artifact), which is also what a run with a persistently failing device
/// converges to.
pub fn calibrate_tesseraq_robust(
    eng: Option<&Engine>,
    params: &mut Params,
    clips: Option<&[BlockClips]>,
    tokens: &[i32],
    n_seq: usize,
    tcfg: &TesseraqConfig,
    robust: &RobustConfig,
) -> Result<CalibReport> {
    // Driver first: it arms the fault plan on the engine before any
    // artifact compile, so compile@ faults reach the optimizer too.
    let driver = ReconstructionDriver::new(eng, robust);
    let size = params.cfg.name.clone();
    let mut opt = ParOptimizer::new(eng, &size, tcfg, clips, n_seq, robust)?;
    driver.run(params, &mut opt, tokens, n_seq)
}

/// TesseraQ (PAR + DST) as a [`BlockOptimizer`].
pub struct ParOptimizer<'a> {
    tcfg: &'a TesseraqConfig,
    clips: Option<&'a [BlockClips]>,
    /// PAR soften-step artifact; unavailable -> hardened RTN per block.
    step_art: Option<Rc<Artifact>>,
    batch: usize,
}

impl<'a> ParOptimizer<'a> {
    pub fn new(
        eng: Option<&Engine>,
        size: &str,
        tcfg: &'a TesseraqConfig,
        clips: Option<&'a [BlockClips]>,
        n_seq: usize,
        robust: &RobustConfig,
    ) -> Result<ParOptimizer<'a>> {
        let scheme = tcfg.qcfg.scheme.tag();
        let step_art = eng.and_then(|e| {
            let name = format!("block_par_step.{size}.{scheme}{}", tcfg.artifact_suffix);
            match with_retry(&robust.retry, &format!("compiling {name}"), || e.artifact(&name)) {
                Ok(a) => Some(a),
                Err(err) => {
                    obs::warn(
                        "degraded",
                        &format!(
                            "[robust] PAR step artifact unavailable; \
                             degrading to hardened RTN per block: {err:#}"
                        ),
                        &[("artifact", name.as_str().into())],
                    );
                    None
                }
            }
        });
        let batch = step_art.as_ref().map_or(1, |a| a.spec.meta.batch.unwrap_or(4));
        if step_art.is_some() {
            ensure!(n_seq % batch == 0, "n_seq {n_seq} not divisible by batch {batch}");
        }
        Ok(ParOptimizer { tcfg, clips, step_art, batch })
    }
}

impl BlockOptimizer for ParOptimizer<'_> {
    fn method_tag(&self) -> &'static str {
        "tesseraq"
    }

    fn config_string(&self) -> String {
        let t = self.tcfg;
        format!(
            "quant={};iters={};steps={};lr={};schedule={:?};par={};dst={};prop={};suffix={}",
            t.qcfg.label(),
            t.iterations,
            t.steps_per_iter,
            t.lr,
            t.schedule,
            t.enable_par,
            t.enable_dst,
            t.propagate_act_quant,
            t.artifact_suffix,
        )
    }

    fn needs_teacher(&self) -> bool {
        // Without a step path every block degrades to hardened RTN and
        // the teacher forward would be wasted work.
        self.step_art.is_some()
    }

    fn propagate_qmax(&self) -> f32 {
        if self.tcfg.propagate_act_quant {
            self.tcfg.qcfg.qmax_act()
        } else {
            quant::A16_SENTINEL
        }
    }

    fn optimize_block(&mut self, ctx: &BlockCtx, bw: &BlockView) -> Result<BlockOutcome> {
        let tcfg = self.tcfg;
        let qmax_w = tcfg.qcfg.qmax_w();
        let qmax_act = tcfg.qcfg.qmax_act();
        let l = ctx.layer;
        let mut states = init_states(bw, self.clips, l, tcfg, qmax_w);
        let mut trace = BlockTrace {
            layer: l,
            losses: Vec::new(),
            flips: BTreeMap::new(),
            initial_loss: f32::NAN,
            status: BlockStatus::Optimized,
        };

        let mut fallback_reason: Option<String> = None;
        match (ctx.eng, &self.step_art, ctx.teacher) {
            (Some(eng), Some(art), Some(teacher)) => {
                // per-block constants live on device for the whole PAR loop
                match BlockConstBufs::new(eng, &bw.norm1, &bw.norm2, &states, qmax_w, qmax_act)
                {
                    Err(e) => {
                        fallback_reason = Some(format!("uploading block constants: {e:#}"))
                    }
                    Ok(consts) => {
                        let mut par = ParLoop {
                            eng,
                            art: art.as_ref(),
                            consts: &consts,
                            set: ctx.set,
                            teacher,
                            batch: self.batch,
                            tcfg,
                            robust: ctx.robust,
                            layer: l,
                            states: &mut states,
                            trace: &mut trace,
                            t_global: 0,
                        };
                        fallback_reason =
                            run_guarded(&mut par, l, tcfg.iterations, ctx.robust.sentinel)?;
                    }
                }
            }
            _ => fallback_reason = Some("no PAR step path available".to_string()),
        }

        let mut quantized = BTreeMap::new();
        if let Some(reason) = fallback_reason {
            obs::warn(
                "fallback",
                &format!("[robust] block {l}: hardened-RTN fallback ({reason})"),
                &[("layer", l.into()), ("reason", reason.as_str().into())],
            );
            trace.losses.clear();
            trace.initial_loss = 0.0;
            trace.status = BlockStatus::RtnFallback;
            for name in LINEAR_NAMES {
                let s = &states[name];
                let w = &bw.linears[name];
                let codes = quant::rtn_codes(w, &s.qp, qmax_w);
                trace.flips.insert(name.to_string(), (0, codes.len()));
                quantized.insert(name.to_string(), (codes, s.qp.clone()));
            }
        } else {
            for name in LINEAR_NAMES {
                let s = &states[name];
                let w_orig = &bw.linears[name];
                trace.flips.insert(
                    name.to_string(),
                    (quant::count_flips(w_orig, &s.nu, &s.qp), s.nu.data.len()),
                );
                let codes = hard_codes(&s.wf, &s.nu, &s.qp, qmax_w);
                let qp_eff = if tcfg.enable_dst {
                    dst_effective_scale(&s.qp, &s.v)
                } else {
                    s.qp.clone()
                };
                quantized.insert(name.to_string(), (codes, qp_eff));
            }
        }
        Ok(BlockOutcome { trace, quantized, extras: BTreeMap::new() })
    }
}

fn init_states(
    bw: &BlockView,
    clips: Option<&[BlockClips]>,
    l: usize,
    tcfg: &TesseraqConfig,
    qmax_w: f32,
) -> BTreeMap<String, LinearState> {
    let mut states = BTreeMap::new();
    for name in LINEAR_NAMES {
        let w = &bw.linears[name];
        let g = tcfg.qcfg.scheme.group_size(w.shape[1]);
        let qp = match clips.and_then(|c| c[l].get(name)) {
            Some((gm, bt)) => minmax_scale(
                w,
                g,
                &ClipFactors::PerGroup(gm.clone()),
                &ClipFactors::PerGroup(bt.clone()),
                qmax_w,
            ),
            None => minmax_scale(
                w,
                g,
                &ClipFactors::Uniform(1.0),
                &ClipFactors::Uniform(1.0),
                qmax_w,
            ),
        };
        states.insert(name.to_string(), LinearState::init(w, qp, !tcfg.enable_par));
    }
    states
}

/// Iteration-start snapshot of everything `par_step` mutates, so a bad
/// iteration can be rolled back exactly (including Adam time `t_global`
/// and the batch index derived from it).
struct ParSnapshot {
    fields: BTreeMap<String, [Tensor; 6]>,
    t_global: u32,
    n_losses: usize,
    initial_loss: f32,
}

impl ParSnapshot {
    fn take(
        states: &BTreeMap<String, LinearState>,
        t_global: u32,
        trace: &BlockTrace,
    ) -> ParSnapshot {
        let fields = states
            .iter()
            .map(|(k, s)| {
                (
                    k.clone(),
                    [
                        s.nu.clone(),
                        s.v.clone(),
                        s.m_nu.clone(),
                        s.u_nu.clone(),
                        s.m_v.clone(),
                        s.u_v.clone(),
                    ],
                )
            })
            .collect();
        ParSnapshot {
            fields,
            t_global,
            n_losses: trace.losses.len(),
            initial_loss: trace.initial_loss,
        }
    }

    fn restore(
        &self,
        states: &mut BTreeMap<String, LinearState>,
        t_global: &mut u32,
        trace: &mut BlockTrace,
    ) {
        for (k, f) in &self.fields {
            if let Some(s) = states.get_mut(k) {
                s.nu = f[0].clone();
                s.v = f[1].clone();
                s.m_nu = f[2].clone();
                s.u_nu = f[3].clone();
                s.m_v = f[4].clone();
                s.u_v = f[5].clone();
            }
        }
        *t_global = self.t_global;
        trace.losses.truncate(self.n_losses);
        trace.initial_loss = self.initial_loss;
    }
}

/// One PAR block's sentinel-guarded loop: each [`GuardedIter::iteration`]
/// hardens per the schedule, then runs `steps_per_iter` soften steps.
struct ParLoop<'a> {
    eng: &'a Engine,
    art: &'a Artifact,
    consts: &'a BlockConstBufs,
    set: &'a CalibSet,
    teacher: &'a Tensor,
    batch: usize,
    tcfg: &'a TesseraqConfig,
    robust: &'a RobustConfig,
    layer: usize,
    states: &'a mut BTreeMap<String, LinearState>,
    trace: &'a mut BlockTrace,
    t_global: u32,
}

impl GuardedIter for ParLoop<'_> {
    type Snap = ParSnapshot;

    fn snapshot(&self) -> ParSnapshot {
        ParSnapshot::take(self.states, self.t_global, self.trace)
    }

    fn restore(&mut self, snap: &ParSnapshot) {
        snap.restore(self.states, &mut self.t_global, self.trace);
    }

    fn iteration(&mut self, k: usize, sentinel: &mut Sentinel) -> Result<Option<IterFailure>> {
        if self.tcfg.enable_par {
            let total_vars: usize = self.states.values().map(|s| s.nu.data.len()).sum();
            let soft = self.tcfg.schedule.soft_rate(k, self.tcfg.iterations);
            let target_hard = total_vars - (soft * total_vars as f32).ceil() as usize;
            harden(self.states, target_hard);
        }
        for _ in 0..self.tcfg.steps_per_iter {
            self.t_global += 1;
            let bi = (self.t_global - 1) as usize;
            let xb = self.set.wrapping_batch(bi, self.batch);
            let yb = self.set.wrapping_slice(self.teacher, bi, self.batch);
            let lr = self.tcfg.lr * sentinel.lr_scale;
            let t = self.t_global as f32;
            let eng = self.eng;
            let art = self.art;
            let consts = self.consts;
            let states = &mut *self.states;
            let step_res = with_retry(&self.robust.retry, "PAR step", || {
                par_step(eng, art, &xb, &yb, consts, &mut *states, lr, t)
            });
            let mut loss = match step_res {
                Ok(loss) => loss,
                Err(e) => return Ok(Some(IterFailure::Exec(format!("{e:#}")))),
            };
            if self
                .robust
                .faults
                .as_ref()
                .is_some_and(|f| f.nan_loss(self.layer, self.t_global as usize))
            {
                loss = f32::NAN;
            }
            match sentinel.observe(loss) {
                LossHealth::Ok => {
                    if self.trace.initial_loss.is_nan() {
                        self.trace.initial_loss = loss;
                    }
                    if !self.tcfg.enable_dst {
                        for s in self.states.values_mut() {
                            s.v = Tensor::zeros(&s.v.shape);
                            s.m_v = Tensor::zeros(&s.v.shape);
                            s.u_v = Tensor::zeros(&s.v.shape);
                        }
                    }
                    self.trace.losses.push(loss);
                }
                LossHealth::NonFinite => {
                    return Ok(Some(IterFailure::Numeric(format!("non-finite loss {loss}"))));
                }
                LossHealth::Diverged { baseline } => {
                    return Ok(Some(IterFailure::Numeric(format!(
                        "loss {loss:.3e} diverged (baseline {baseline:.3e})"
                    ))));
                }
            }
        }
        if obs::enabled() {
            // soften-progress series: loss + hardened fraction per PAR iter
            let total: usize = self.states.values().map(|s| s.nu.data.len()).sum();
            let hard: usize = self
                .states
                .values()
                .map(|s| s.nu.data.iter().filter(|x| x.abs() >= SAT_NU).count())
                .sum();
            obs::event(
                "par_iter",
                &[
                    ("layer", self.layer.into()),
                    ("iter", k.into()),
                    ("loss", self.trace.losses.last().copied().unwrap_or(f32::NAN).into()),
                    ("hard_frac", (hard as f64 / total.max(1) as f64).into()),
                    ("lr_scale", sentinel.lr_scale.into()),
                ],
            );
        }
        Ok(None)
    }
}

/// Harden phase: pool HS(nu) = |sigmoid(nu) - 0.5| across all linears of
/// the block, saturate the `target_hard` lowest-scoring variables and
/// reset their Adam state.
fn harden(states: &mut BTreeMap<String, LinearState>, target_hard: usize) {
    let total: usize = states.values().map(|s| s.nu.data.len()).sum();
    let already: usize = states
        .values()
        .map(|s| s.nu.data.iter().filter(|x| x.abs() >= SAT_NU).count())
        .sum();
    let target = target_hard.min(total);
    if target <= already {
        return; // cumulative target: never un-harden
    }
    let need = target - already;
    // scores of SOFT variables only, pooled across the block's linears
    let mut scores: Vec<f32> = Vec::with_capacity(total - already);
    for s in states.values() {
        scores.extend(
            s.nu
                .data
                .iter()
                .filter(|x| x.abs() < SAT_NU)
                .map(|&x| (quant::sigmoid(x) - 0.5).abs()),
        );
    }
    let thr = if need >= scores.len() {
        f32::INFINITY
    } else {
        let (_, nth, _) =
            scores.select_nth_unstable_by(need - 1, |a, b| a.total_cmp(b));
        *nth
    };
    let mut hardened = 0usize;
    for s in states.values_mut() {
        for idx in 0..s.nu.data.len() {
            let x = s.nu.data[idx];
            if x.abs() >= SAT_NU {
                continue;
            }
            let score = (quant::sigmoid(x) - 0.5).abs();
            // tie-break: stop once the quota is filled
            if score < thr || (score == thr && hardened < need) {
                s.nu.data[idx] = if x > 0.0 { SAT_NU } else { -SAT_NU };
                s.m_nu.data[idx] = 0.0;
                s.u_nu.data[idx] = 0.0;
                hardened += 1;
            }
        }
    }
}

/// Device-resident per-block constants (perf: §Perf L3 — uploading the
/// weight grid and scales once per block instead of per step removes
/// ~40% of the per-step host->device traffic; see benches/calib_step).
struct BlockConstBufs {
    norm1: xla::PjRtBuffer,
    norm2: xla::PjRtBuffer,
    /// (wf, s, z) per linear in LINEAR_NAMES order
    per_linear: Vec<[xla::PjRtBuffer; 3]>,
    qmax_w: xla::PjRtBuffer,
    qmax_act: xla::PjRtBuffer,
}

impl BlockConstBufs {
    fn new(
        eng: &Engine,
        norm1: &Tensor,
        norm2: &Tensor,
        states: &BTreeMap<String, LinearState>,
        qmax_w: f32,
        qmax_act: f32,
    ) -> Result<Self> {
        let per_linear = LINEAR_NAMES
            .iter()
            .map(|name| {
                let s = &states[*name];
                Ok([
                    eng.upload(&s.wf)?,
                    eng.upload(&s.qp.s)?,
                    eng.upload(&s.qp.z)?,
                ])
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(BlockConstBufs {
            norm1: eng.upload(norm1)?,
            norm2: eng.upload(norm2)?,
            per_linear,
            qmax_w: eng.upload_scalar(qmax_w)?,
            qmax_act: eng.upload_scalar(qmax_act)?,
        })
    }
}

/// One soften-phase Adam step through the artifact; returns the loss and
/// updates all host-side state in place.
fn par_step(
    eng: &Engine,
    art: &Artifact,
    x: &Tensor,
    y: &Tensor,
    consts: &BlockConstBufs,
    states: &mut BTreeMap<String, LinearState>,
    lr: f32,
    t: f32,
) -> Result<f32> {
    // mutable state uploads (fresh every step)
    let xb = eng.upload(x)?;
    let yb = eng.upload(y)?;
    let mut var_bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(6 * LINEAR_NAMES.len());
    for field in ["nu", "v", "m_nu", "u_nu", "m_v", "u_v"] {
        for name in LINEAR_NAMES {
            let s = &states[name];
            let t = match field {
                "nu" => &s.nu,
                "v" => &s.v,
                "m_nu" => &s.m_nu,
                "u_nu" => &s.u_nu,
                "m_v" => &s.m_v,
                _ => &s.u_v,
            };
            var_bufs.push(eng.upload(t)?);
        }
    }
    let lr_b = eng.upload_scalar(lr)?;
    let t_b = eng.upload_scalar(t)?;

    let mut bufs: Vec<&xla::PjRtBuffer> = vec![&xb, &yb, &consts.norm1, &consts.norm2];
    for triple in &consts.per_linear {
        bufs.extend([&triple[0], &triple[1], &triple[2]]);
    }
    bufs.extend(var_bufs.iter());
    bufs.push(&lr_b);
    bufs.push(&t_b);
    bufs.push(&consts.qmax_w);
    bufs.push(&consts.qmax_act);

    let outs = eng.run_buffers(art, &bufs)?;
    let loss = outs[0].data[0];
    let n = LINEAR_NAMES.len();
    for (fi, field) in ["nu", "v", "m_nu", "u_nu", "m_v", "u_v"].iter().enumerate() {
        for (li, name) in LINEAR_NAMES.iter().enumerate() {
            let t = outs[1 + fi * n + li].clone();
            let s = states.get_mut(*name).expect("state exists for every linear name");
            match *field {
                "nu" => s.nu = t,
                "v" => s.v = t,
                "m_nu" => s.m_nu = t,
                "u_nu" => s.u_nu = t,
                "m_v" => s.m_v = t,
                _ => s.u_v = t,
            }
        }
    }
    Ok(loss)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harden_saturates_lowest_scores() {
        let mut states = BTreeMap::new();
        let w = Tensor::from_fn(&[2, 8], |i| (i as f32 - 8.0) * 0.13 + 0.01);
        let qp = minmax_scale(&w, 8, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), 3.0);
        states.insert("q_proj".to_string(), LinearState::init(&w, qp, false));
        let before_hard: usize = states["q_proj"]
            .nu
            .data
            .iter()
            .filter(|x| x.abs() >= SAT_NU)
            .count();
        assert_eq!(before_hard, 0);
        harden(&mut states, 10);
        let after: usize = states["q_proj"]
            .nu
            .data
            .iter()
            .filter(|x| x.abs() >= SAT_NU)
            .count();
        assert!(after >= 10, "hardened {after} < 10");
        // monotone: hardening to a smaller target is a no-op
        harden(&mut states, 5);
        let after2: usize = states["q_proj"]
            .nu
            .data
            .iter()
            .filter(|x| x.abs() >= SAT_NU)
            .count();
        assert_eq!(after, after2);
    }

    #[test]
    fn hardened_start_is_rtn() {
        let w = Tensor::from_fn(&[2, 8], |i| i as f32 * 0.37 - 1.0);
        let qp = minmax_scale(&w, 8, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), 3.0);
        let st = LinearState::init(&w, qp.clone(), true);
        assert!(st.nu.data.iter().all(|x| x.abs() >= SAT_NU));
        // hard codes == RTN codes
        let hard = hard_codes(&st.wf, &st.nu, &qp, 3.0);
        let rtn = quant::rtn_codes(&w, &qp, 3.0);
        assert_eq!(hard, rtn);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let w = Tensor::from_fn(&[2, 8], |i| i as f32 * 0.21 - 1.3);
        let qp = minmax_scale(&w, 8, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), 3.0);
        let mut states = BTreeMap::new();
        states.insert("q_proj".to_string(), LinearState::init(&w, qp, false));
        let mut trace = BlockTrace {
            layer: 0,
            losses: vec![1.0, 0.5],
            flips: BTreeMap::new(),
            initial_loss: 1.0,
            status: BlockStatus::Optimized,
        };
        let mut t_global = 7u32;
        let snap = ParSnapshot::take(&states, t_global, &trace);
        // corrupt everything the soften loop mutates
        for s in states.values_mut() {
            for x in s.nu.data.iter_mut() {
                *x = f32::NAN;
            }
            s.m_nu = Tensor::full(&s.m_nu.shape, 9.0);
        }
        trace.losses.push(f32::NAN);
        trace.initial_loss = f32::NAN;
        t_global = 99;
        snap.restore(&mut states, &mut t_global, &mut trace);
        assert_eq!(t_global, 7);
        assert_eq!(trace.losses, vec![1.0, 0.5]);
        assert_eq!(trace.initial_loss, 1.0);
        assert!(states["q_proj"].nu.data.iter().all(|x| x.is_finite()));
        assert!(states["q_proj"].m_nu.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn par_optimizer_config_string_tracks_knobs() {
        let qcfg = QuantConfig::weight_only(2, crate::quant::GroupScheme::Group(32));
        let tcfg = TesseraqConfig::fast(qcfg);
        let robust = RobustConfig::disabled();
        let a = ParOptimizer::new(None, "nano", &tcfg, None, 4, &robust).unwrap();
        let mut t2 = tcfg.clone();
        t2.lr *= 2.0;
        let b = ParOptimizer::new(None, "nano", &t2, None, 4, &robust).unwrap();
        assert_eq!(a.method_tag(), "tesseraq");
        assert_ne!(a.config_string(), b.config_string(), "lr changes config string");
        assert!(!a.needs_teacher(), "no step artifact -> no teacher needed");
    }
}
