//! Pretraining driver: runs the AOT `model_train_step` artifact for a few
//! hundred Adam steps on a synthetic corpus — the E2E requirement that the
//! whole three-layer stack composes (DESIGN.md §6). Rust owns the loop,
//! data order, LR schedule and loss logging; the artifact owns the math.

use anyhow::Result;

use crate::data::Corpus;
use crate::model::Params;
use crate::runtime::{Arg, Engine};
use crate::tensor::Tensor;

pub struct PretrainConfig {
    pub steps: usize,
    pub lr: f32,
    /// cosine decay to lr_min over the run
    pub lr_min: f32,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for PretrainConfig {
    fn default() -> Self {
        PretrainConfig { steps: 300, lr: 3e-3, lr_min: 3e-4, seed: 0, log_every: 20 }
    }
}

pub struct PretrainReport {
    pub losses: Vec<f32>,
    pub wall_s: f64,
}

/// Train `params` in place on the corpus; returns the loss curve.
pub fn pretrain(
    eng: &Engine,
    params: &mut Params,
    corpus: &Corpus,
    pcfg: &PretrainConfig,
    mut log: impl FnMut(usize, f32),
) -> Result<PretrainReport> {
    let t0 = std::time::Instant::now();
    let size = params.cfg.name.clone();
    let art = eng.artifact(&format!("model_train_step.{size}"))?;
    let b = art.spec.meta.train_batch;
    let t = params.cfg.max_seq;

    let mut m = params.zeros_like();
    let mut u = params.zeros_like();
    let mut losses = Vec::with_capacity(pcfg.steps);

    for step in 1..=pcfg.steps {
        let tokens = corpus.sequences(b, t, pcfg.seed.wrapping_add(step as u64 * 131));
        let x = step as f32 / pcfg.steps as f32;
        let lr = pcfg.lr_min
            + 0.5 * (pcfg.lr - pcfg.lr_min) * (1.0 + (std::f32::consts::PI * x).cos());

        let p_ord = params.ordered();
        let m_ord = m.ordered();
        let u_ord = u.ordered();
        let tok_shape = [b, t];
        let mut args: Vec<Arg> = vec![Arg::I32(&tokens, &tok_shape)];
        args.extend(p_ord.iter().map(|t| Arg::F32(t)));
        args.extend(m_ord.iter().map(|t| Arg::F32(t)));
        args.extend(u_ord.iter().map(|t| Arg::F32(t)));
        args.push(Arg::Scalar(lr));
        args.push(Arg::Scalar(step as f32));

        let outs = eng.run(&art, &args)?;
        let loss = outs[0].data[0];
        losses.push(loss);
        let n = crate::model::PARAM_NAMES.len();
        let new_p: Vec<Tensor> = outs[1..1 + n].to_vec();
        let new_m: Vec<Tensor> = outs[1 + n..1 + 2 * n].to_vec();
        let new_u: Vec<Tensor> = outs[1 + 2 * n..1 + 3 * n].to_vec();
        params.set_ordered(&new_p);
        m.set_ordered(&new_m);
        u.set_ordered(&new_u);

        if step % pcfg.log_every == 0 || step == 1 {
            log(step, loss);
        }
    }
    Ok(PretrainReport { losses, wall_s: t0.elapsed().as_secs_f64() })
}
