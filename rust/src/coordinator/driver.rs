//! The unified block-reconstruction driver — ONE resumable, sentinel-
//! guarded block loop shared by every reconstruction-style PTQ method
//! (TesseraQ/PAR, OmniQuant/LWC, GPTQ).
//!
//! The skeleton every method shares — FP teacher targets on the
//! quantized-prefix stream, per-block optimization, merging the final
//! codes into the model, propagating the student stream — lives here
//! exactly once. A method plugs in as a [`BlockOptimizer`]; the
//! [`ReconstructionDriver`] owns the `CalibSet`, the `ForwardBackend`
//! (device artifact with retries, host reference fallback), per-block
//! `.tsqb` checkpointing keyed by a fingerprint that includes the
//! optimizer's method tag, resume (restored blocks are re-merged and the
//! stream rebuilt through them, bit-identically), and the fault-injection
//! kill site. Iterative optimizers additionally reuse the sentinel
//! rollback loop via [`GuardedIter`]/[`run_guarded`].

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::pipeline::{CalibSet, ForwardBackend};
use crate::model::{hostfwd, BlockView, Params};
use crate::obs;
use crate::quant::{self, dequant_codes, QParams, QuantConfig};
use crate::robust::checkpoint::fnv1a64;
use crate::robust::{
    BlockCheckpoint, CheckpointStore, RobustConfig, Sentinel, SentinelConfig, KILL_MARKER,
};
use crate::runtime::Engine;
use crate::tensor::Tensor;

/// How a block's final codes were produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockStatus {
    /// The method's full optimization ran to completion.
    Optimized,
    /// The resilience layer degraded this block to its RTN-style fallback
    /// (sentinel retry budget exhausted, or no step path available).
    RtnFallback,
}

/// Per-block calibration record (Fig. 4 traces + Table 7 flip stats).
#[derive(Debug, Clone, PartialEq)]
pub struct BlockTrace {
    pub layer: usize,
    /// reconstruction MSE after each optimization step
    pub losses: Vec<f32>,
    /// per linear: (flipped vs RTN, total rounding variables)
    pub flips: BTreeMap<String, (usize, usize)>,
    /// loss right before any optimization (RTN-equivalent start)
    pub initial_loss: f32,
    pub status: BlockStatus,
}

pub struct CalibReport {
    pub per_block: Vec<BlockTrace>,
    /// per block, per linear: final integer codes + effective dequant
    /// params — ready for packing/serving.
    pub quantized: Vec<BTreeMap<String, (Vec<u16>, QParams)>>,
    pub wall_s: f64,
}

impl CalibReport {
    /// Blocks the resilience layer degraded to RTN.
    pub fn fallback_blocks(&self) -> Vec<usize> {
        self.per_block
            .iter()
            .filter(|t| t.status == BlockStatus::RtnFallback)
            .map(|t| t.layer)
            .collect()
    }

    /// Serialize the calibration record (per-block traces, fallback list,
    /// wall time) as JSON — the machine-readable artifact written next to
    /// the markdown tables.
    pub fn to_json(&self) -> String {
        use crate::util::json::Json;
        let mut root = BTreeMap::new();
        root.insert("wall_s".to_string(), Json::Num(self.wall_s));
        root.insert(
            "fallback_blocks".to_string(),
            Json::Arr(self.fallback_blocks().iter().map(|&l| Json::Num(l as f64)).collect()),
        );
        let blocks = self
            .per_block
            .iter()
            .map(|t| {
                let mut b = BTreeMap::new();
                b.insert("layer".to_string(), Json::Num(t.layer as f64));
                b.insert(
                    "status".to_string(),
                    Json::Str(
                        match t.status {
                            BlockStatus::Optimized => "optimized",
                            BlockStatus::RtnFallback => "rtn_fallback",
                        }
                        .to_string(),
                    ),
                );
                b.insert("initial_loss".to_string(), Json::Num(t.initial_loss as f64));
                b.insert(
                    "losses".to_string(),
                    Json::Arr(t.losses.iter().map(|&v| Json::Num(v as f64)).collect()),
                );
                let flips = t
                    .flips
                    .iter()
                    .map(|(name, &(moved, total))| {
                        (
                            name.clone(),
                            Json::Arr(vec![Json::Num(moved as f64), Json::Num(total as f64)]),
                        )
                    })
                    .collect();
                b.insert("flips".to_string(), Json::Obj(flips));
                Json::Obj(b)
            })
            .collect();
        root.insert("per_block".to_string(), Json::Arr(blocks));
        Json::Obj(root).dump()
    }
}

/// One block's result, handed back to the driver for merge + checkpoint.
pub struct BlockOutcome {
    pub trace: BlockTrace,
    pub quantized: BTreeMap<String, (Vec<u16>, QParams)>,
    /// Method-specific side state persisted alongside the codes (e.g. the
    /// LWC clip tensors) so resume can rebuild it; empty for methods
    /// without any.
    pub extras: BTreeMap<String, Tensor>,
}

/// Everything the driver lends an optimizer for one block.
pub struct BlockCtx<'a> {
    pub layer: usize,
    pub eng: Option<&'a Engine>,
    pub backend: &'a ForwardBackend<'a>,
    pub set: &'a CalibSet,
    /// FP teacher outputs for this block on the quantized-prefix stream;
    /// `None` when the optimizer reported it needs no teacher.
    pub teacher: Option<&'a Tensor>,
    pub robust: &'a RobustConfig,
}

/// A reconstruction-style PTQ method, pluggable into the driver.
pub trait BlockOptimizer {
    /// Stable tag mixed into the checkpoint fingerprint (and the per-run
    /// checkpoint subdirectory name).
    fn method_tag(&self) -> &'static str;

    /// Every knob that affects this optimizer's outputs, serialized for
    /// the fingerprint. Two runs with equal config strings (and equal
    /// model/tokens) must produce bit-identical blocks.
    fn config_string(&self) -> String;

    /// Should the driver compute FP teacher targets for each block?
    fn needs_teacher(&self) -> bool {
        true
    }

    /// qmax for propagating the student stream between blocks
    /// (`A16_SENTINEL` = FP activations).
    fn propagate_qmax(&self) -> f32;

    fn optimize_block(&mut self, ctx: &BlockCtx, bw: &BlockView) -> Result<BlockOutcome>;

    /// Called for each block restored from a checkpoint on resume, so the
    /// optimizer can rebuild any side state it keeps (default: ignore).
    fn observe_restored(&mut self, _layer: usize, _ckpt: &BlockCheckpoint) {}
}

/// The one block-loop skeleton. Construct with the run's engine handle
/// and resilience knobs, then [`run`](ReconstructionDriver::run) any
/// [`BlockOptimizer`] over the model in place.
pub struct ReconstructionDriver<'a> {
    eng: Option<&'a Engine>,
    robust: &'a RobustConfig,
}

impl<'a> ReconstructionDriver<'a> {
    pub fn new(eng: Option<&'a Engine>, robust: &'a RobustConfig) -> Self {
        // Arm engine-level fault injection before any artifact compiles.
        if let (Some(e), Some(plan)) = (eng, &robust.faults) {
            e.set_fault_plan(Some(plan.clone()));
        }
        ReconstructionDriver { eng, robust }
    }

    pub fn run(
        &self,
        params: &mut Params,
        opt: &mut dyn BlockOptimizer,
        tokens: &[i32],
        n_seq: usize,
    ) -> Result<CalibReport> {
        let t0 = Instant::now();
        let size = params.cfg.name.clone();
        let backend = ForwardBackend::new(self.eng, &params.cfg, &size, &self.robust.retry);
        let n_layers = params.cfg.n_layers;

        // Checkpoint store under a per-run subdirectory so different
        // methods/configs sharing one --checkpoint-dir never collide.
        let fingerprint = run_fingerprint(params, opt, tokens, n_seq);
        let store = match &self.robust.checkpoint_dir {
            Some(dir) => {
                let sub = dir.join(format!("{}_{fingerprint:016x}", opt.method_tag()));
                Some(CheckpointStore::new(sub, fingerprint)?)
            }
            None => None,
        };
        obs::run_start(
            fingerprint,
            opt.method_tag(),
            &[
                ("model", size.as_str().into()),
                ("n_layers", n_layers.into()),
                ("n_seq", n_seq.into()),
                ("resume", self.robust.resume.into()),
            ],
        );

        let mut per_block: Vec<BlockTrace> = Vec::new();
        let mut quantized: Vec<BTreeMap<String, (Vec<u16>, QParams)>> = Vec::new();
        if let Some(store) = &store {
            if self.robust.resume {
                for ckpt in store.load_prefix(n_layers) {
                    merge_block(params, ckpt.trace.layer, &ckpt.quantized);
                    opt.observe_restored(ckpt.trace.layer, &ckpt);
                    per_block.push(ckpt.trace);
                    quantized.push(ckpt.quantized);
                }
                if !per_block.is_empty() {
                    obs::warn(
                        "resume",
                        &format!(
                            "[robust] resuming: {}/{} blocks restored from {}",
                            per_block.len(),
                            n_layers,
                            store.dir().display()
                        ),
                        &[
                            ("restored", per_block.len().into()),
                            ("n_layers", n_layers.into()),
                        ],
                    );
                }
            } else {
                store.clear()?;
            }
        }
        let start_block = per_block.len();

        let mut set = CalibSet::from_tokens(params, tokens, n_seq)?;
        let prop_qmax = opt.propagate_qmax();
        // Rebuild the residual stream through the restored (already
        // merged) prefix — the same forward ops as the original pass, so
        // a resumed run reproduces the interrupted run bit for bit.
        if start_block > 0 {
            let _sp = crate::span!("rebuild_prefix", start_block);
            for l in 0..start_block {
                let bw_q = params.block(l);
                set.x = backend.forward_all(&bw_q, &set, prop_qmax)?;
            }
        }

        for l in start_block..n_layers {
            let _sp_block = crate::span!("block", l);
            let t_block = Instant::now();
            let bw = params.block(l);
            let teacher = if opt.needs_teacher() {
                let _sp = crate::span!("teacher");
                Some(backend.forward_all(&bw, &set, quant::A16_SENTINEL)?)
            } else {
                None
            };
            let ctx = BlockCtx {
                layer: l,
                eng: self.eng,
                backend: &backend,
                set: &set,
                teacher: teacher.as_ref(),
                robust: self.robust,
            };
            let outcome = {
                let _sp = crate::span!("optimize");
                opt.optimize_block(&ctx, &bw)?
            };
            merge_block(params, l, &outcome.quantized);
            if let Some(store) = &store {
                store.save_block(
                    l,
                    &BlockCheckpoint {
                        trace: outcome.trace.clone(),
                        quantized: outcome.quantized.clone(),
                        extras: outcome.extras.clone(),
                    },
                )?;
            }
            if obs::enabled() {
                let t = &outcome.trace;
                let final_loss = t.losses.last().copied().unwrap_or(t.initial_loss);
                obs::event(
                    "block_done",
                    &[
                        ("layer", l.into()),
                        (
                            "status",
                            match t.status {
                                BlockStatus::Optimized => "optimized",
                                BlockStatus::RtnFallback => "rtn_fallback",
                            }
                            .into(),
                        ),
                        ("initial_loss", t.initial_loss.into()),
                        ("final_loss", final_loss.into()),
                        ("steps", t.losses.len().into()),
                        ("wall_ms", (t_block.elapsed().as_secs_f64() * 1e3).into()),
                    ],
                );
            }
            per_block.push(outcome.trace);
            quantized.push(outcome.quantized);
            if self.robust.faults.as_ref().is_some_and(|f| f.kill_after_block(l)) {
                bail!("{KILL_MARKER} after block {l}");
            }
            // propagate the stream through the merged quantized block
            let bw_q = params.block(l);
            set.x = {
                let _sp = crate::span!("propagate");
                backend.forward_all(&bw_q, &set, prop_qmax)?
            };
        }

        let wall_s = t0.elapsed().as_secs_f64();
        obs::flush_metrics();
        obs::event(
            "run_end",
            &[
                ("method", opt.method_tag().into()),
                ("blocks", per_block.len().into()),
                ("wall_s", wall_s.into()),
            ],
        );
        Ok(CalibReport { per_block, quantized, wall_s })
    }
}

/// Hash of everything that determines a run's outputs: the checkpoint
/// format version, the optimizer's method tag and config string, the
/// model name, the calibration tokens, and the (embedding) weights.
/// Stored in every block checkpoint; a mismatch refuses resume.
pub fn run_fingerprint(
    params: &Params,
    opt: &dyn BlockOptimizer,
    tokens: &[i32],
    n_seq: usize,
) -> u64 {
    let mut bytes = format!(
        "v{};method={};model={};cfg={};n_seq={}",
        crate::robust::checkpoint::VERSION,
        opt.method_tag(),
        params.cfg.name,
        opt.config_string(),
        n_seq,
    )
    .into_bytes();
    for &t in tokens {
        bytes.extend_from_slice(&t.to_le_bytes());
    }
    // cheap weight identity: the embedding table's raw bits
    for &v in &params.get("emb").data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    fnv1a64(&bytes)
}

/// Merge one block's final codes into the model (fake-quant weights).
/// Both fresh and resumed runs merge through this exact f32 dequant, which
/// is what makes resume bit-identical for every method.
pub fn merge_block(
    params: &mut Params,
    layer: usize,
    qblock: &BTreeMap<String, (Vec<u16>, QParams)>,
) {
    for (name, (codes, qp)) in qblock {
        let o = qp.s.shape[0];
        let i = codes.len() / o;
        let wq = dequant_codes(codes, o, i, qp);
        params.set_block_linear(layer, name, &wq);
    }
}

/// A recoverable failure inside one guarded iteration.
pub enum IterFailure {
    /// Step execution kept failing after retries — not recoverable by
    /// rollback; degrade the block.
    Exec(String),
    /// NaN/Inf/diverged loss — recoverable by rollback + LR backoff.
    Numeric(String),
}

/// A sentinel-guarded optimization loop: the driver owns snapshotting,
/// rollback, and the retry budget; the optimizer owns the per-iteration
/// math. `snapshot`/`restore` must round-trip everything `iteration`
/// mutates (including loss traces), so a rolled-back iteration leaves no
/// residue.
pub trait GuardedIter {
    type Snap;

    fn snapshot(&self) -> Self::Snap;

    fn restore(&mut self, snap: &Self::Snap);

    /// Run iteration `k` (1-based). The sentinel supplies the retry-scaled
    /// learning rate (`lr_scale`) and classifies losses via `observe`.
    fn iteration(&mut self, k: usize, sentinel: &mut Sentinel) -> Result<Option<IterFailure>>;
}

/// Run `iterations` guarded iterations over `g`. `Ok(None)` = completed;
/// `Ok(Some(reason))` = degrade this block to its fallback.
pub fn run_guarded<G: GuardedIter>(
    g: &mut G,
    layer: usize,
    iterations: usize,
    scfg: SentinelConfig,
) -> Result<Option<String>> {
    let mut sentinel = Sentinel::new(scfg);
    let mut k = 1;
    while k <= iterations {
        let snap = g.snapshot();
        match g.iteration(k, &mut sentinel)? {
            None => k += 1,
            Some(IterFailure::Exec(reason)) => {
                return Ok(Some(format!("step execution: {reason}")));
            }
            Some(IterFailure::Numeric(reason)) => match sentinel.trip() {
                Some(scale) => {
                    obs::warn(
                        "rollback",
                        &format!(
                            "[robust] block {layer} iteration {k}: {reason}; rolling back to \
                             the iteration-start snapshot, retrying with lr scale {scale}"
                        ),
                        &[
                            ("layer", layer.into()),
                            ("iter", k.into()),
                            ("reason", reason.as_str().into()),
                            ("lr_scale", scale.into()),
                        ],
                    );
                    g.restore(&snap);
                }
                None => {
                    return Ok(Some(format!(
                        "{reason} after {} rollbacks",
                        sentinel.retries_used()
                    )));
                }
            },
        }
    }
    Ok(None)
}

/// GPTQ as a [`BlockOptimizer`]: per-linear Hessian-compensated rounding
/// on host-collected activation taps. No teacher targets, no step loop —
/// one deterministic pass per block.
pub struct GptqOptimizer {
    qcfg: QuantConfig,
    damp: f64,
}

impl GptqOptimizer {
    pub fn new(qcfg: QuantConfig) -> Self {
        GptqOptimizer { qcfg, damp: 0.01 }
    }
}

impl BlockOptimizer for GptqOptimizer {
    fn method_tag(&self) -> &'static str {
        "gptq"
    }

    fn config_string(&self) -> String {
        format!("quant={};damp={}", self.qcfg.label(), self.damp)
    }

    fn needs_teacher(&self) -> bool {
        false
    }

    fn propagate_qmax(&self) -> f32 {
        self.qcfg.qmax_act()
    }

    fn optimize_block(&mut self, ctx: &BlockCtx, bw: &BlockView) -> Result<BlockOutcome> {
        // Collect per-linear input taps with one host forward over the
        // quantized-prefix stream (A16 sentinel = FP passthrough).
        let opts = hostfwd::BlockFwdOpts {
            act_qmax: Some(self.qcfg.qmax_act()),
            collect: true,
        };
        let (_, taps) = hostfwd::block_fwd(&ctx.set.x, bw, &ctx.backend.cfg, &opts);
        let qmax = self.qcfg.qmax_w();
        let mut trace = BlockTrace {
            layer: ctx.layer,
            losses: Vec::new(),
            flips: BTreeMap::new(),
            initial_loss: 0.0,
            status: BlockStatus::Optimized,
        };
        let mut quantized = BTreeMap::new();
        for (name, w) in &bw.linears {
            let tap = taps
                .get(hostfwd::tap_for_linear(name))
                .with_context(|| format!("no activation tap for {name}"))?;
            let out = crate::baselines::gptq::gptq_linear(w, tap, &self.qcfg, self.damp);
            // flips vs plain RTN on the same final grid — how many codes
            // the error compensation actually moved
            let rtn = quant::rtn_codes(w, &out.qp, qmax);
            let moved = out.codes.iter().zip(&rtn).filter(|(a, b)| a != b).count();
            trace.flips.insert(name.clone(), (moved, out.codes.len()));
            quantized.insert(name.clone(), (out.codes, out.qp));
        }
        Ok(BlockOutcome { trace, quantized, extras: BTreeMap::new() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::quant::GroupScheme;
    use crate::tensor::Pcg32;

    struct TagOnly(&'static str, String);

    impl BlockOptimizer for TagOnly {
        fn method_tag(&self) -> &'static str {
            self.0
        }
        fn config_string(&self) -> String {
            self.1.clone()
        }
        fn propagate_qmax(&self) -> f32 {
            quant::A16_SENTINEL
        }
        fn optimize_block(&mut self, _: &BlockCtx, _: &BlockView) -> Result<BlockOutcome> {
            unreachable!("fingerprint tests never run blocks")
        }
    }

    #[test]
    fn fingerprint_tracks_method_config_and_data() {
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(0);
        let p = Params::init(&cfg, &mut rng);
        let tokens: Vec<i32> = (0..64).map(|i| i % 100).collect();
        let a = run_fingerprint(&p, &TagOnly("m1", "lr=1".into()), &tokens, 4);
        assert_eq!(
            a,
            run_fingerprint(&p, &TagOnly("m1", "lr=1".into()), &tokens, 4),
            "deterministic"
        );
        assert_ne!(
            a,
            run_fingerprint(&p, &TagOnly("m2", "lr=1".into()), &tokens, 4),
            "method tag changes fingerprint"
        );
        assert_ne!(
            a,
            run_fingerprint(&p, &TagOnly("m1", "lr=2".into()), &tokens, 4),
            "config changes fingerprint"
        );
        let mut tok2 = tokens.clone();
        tok2[0] += 1;
        assert_ne!(
            a,
            run_fingerprint(&p, &TagOnly("m1", "lr=1".into()), &tok2, 4),
            "tokens change fingerprint"
        );
    }

    #[test]
    fn gptq_optimizer_flips_are_bounded() {
        // sanity on the flip metric: every count <= total
        let qcfg = QuantConfig::weight_only(2, GroupScheme::Group(32));
        let opt = GptqOptimizer::new(qcfg);
        assert_eq!(opt.method_tag(), "gptq");
        assert!(!opt.needs_teacher());
        assert_eq!(opt.propagate_qmax(), quant::A16_SENTINEL);
    }
}
