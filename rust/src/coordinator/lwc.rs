//! OmniQuant-style Learnable Weight Clipping baseline: block-wise
//! reconstruction over per-group clip logits (gamma, beta) with STE,
//! driven through the `block_lwc_step` artifact. Produces the clip
//! factors TesseraQ uses for its W2A16 initialization (paper §4.1).

use std::collections::BTreeMap;

use anyhow::{ensure, Context, Result};

use crate::coordinator::par::BlockClips;
use crate::coordinator::pipeline::{BlockRunner, CalibSet};
use crate::model::{Params, LINEAR_NAMES};
use crate::quant::{self, minmax_scale, rtn_qdq, ClipFactors, QuantConfig};
use crate::runtime::{Arg, Engine};
use crate::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct LwcConfig {
    pub qcfg: QuantConfig,
    pub steps: usize,
    pub lr: f32,
    pub propagate_act_quant: bool,
}

impl LwcConfig {
    pub fn standard(qcfg: QuantConfig) -> Self {
        LwcConfig { qcfg, steps: 120, lr: 5e-2, propagate_act_quant: false }
    }

    pub fn fast(qcfg: QuantConfig) -> Self {
        LwcConfig { steps: 24, ..Self::standard(qcfg) }
    }
}

pub struct LwcReport {
    /// learned per-block clip factors (sigmoid of the raw logits)
    pub clips: Vec<BlockClips>,
    pub losses: Vec<Vec<f32>>,
}

/// Run LWC calibration in place (weights become fake-quantized) and
/// return the learned clips (reusable as a TesseraQ initializer).
pub fn calibrate_lwc(
    eng: &Engine,
    params: &mut Params,
    tokens: &[i32],
    n_seq: usize,
    lcfg: &LwcConfig,
) -> Result<LwcReport> {
    let size = params.cfg.name.clone();
    let scheme = lcfg.qcfg.scheme.tag();
    let runner = BlockRunner::new(eng, &size)?;
    let art = eng
        .artifact(&format!("block_lwc_step.{size}.{scheme}"))
        .with_context(|| format!("no LWC artifact for {size}/{scheme}"))?;
    let batch = art.spec.meta.batch.unwrap_or(4);
    ensure!(n_seq % batch == 0);

    let qmax_w = lcfg.qcfg.qmax_w();
    let qmax_act = lcfg.qcfg.qmax_act();
    let mut set = CalibSet::from_tokens(params, tokens, n_seq);
    let mut clips_out = Vec::new();
    let mut losses_out = Vec::new();

    for l in 0..params.cfg.n_layers {
        let bw = params.block(l);
        let y_all = runner.forward_all(&bw, &set, quant::A16_SENTINEL)?;

        // state: raw logits init 4.0 (sigmoid ~ 0.982, near-identity clip)
        let mut gam: BTreeMap<String, Tensor> = BTreeMap::new();
        let mut bet: BTreeMap<String, Tensor> = BTreeMap::new();
        let mut adam: BTreeMap<String, [Tensor; 4]> = BTreeMap::new();
        for name in LINEAR_NAMES {
            let w = &bw.linears[name];
            let g = lcfg.qcfg.scheme.group_size(w.shape[1]);
            let ng = w.shape[1] / g;
            let shape = vec![w.shape[0], ng];
            gam.insert(name.to_string(), Tensor::full(&shape, 4.0));
            bet.insert(name.to_string(), Tensor::full(&shape, 4.0));
            adam.insert(
                name.to_string(),
                [
                    Tensor::zeros(&shape),
                    Tensor::zeros(&shape),
                    Tensor::zeros(&shape),
                    Tensor::zeros(&shape),
                ],
            );
        }

        let mut losses = Vec::new();
        for t in 1..=lcfg.steps {
            let bi = t - 1;
            let xb = set.batch(bi, batch);
            let per = set.t * set.d * batch;
            let start = (bi % set.n_batches(batch)) * per;
            let yb = Tensor::new(
                vec![batch, set.t, set.d],
                y_all.data[start..start + per].to_vec(),
            );

            let mut args: Vec<Arg> =
                vec![Arg::F32(&xb), Arg::F32(&yb), Arg::F32(&bw.norm1), Arg::F32(&bw.norm2)];
            for name in LINEAR_NAMES {
                args.push(Arg::F32(&bw.linears[name]));
            }
            for name in LINEAR_NAMES {
                args.push(Arg::F32(&gam[name]));
            }
            for name in LINEAR_NAMES {
                args.push(Arg::F32(&bet[name]));
            }
            for s in 0..4 {
                for name in LINEAR_NAMES {
                    args.push(Arg::F32(&adam[name][s]));
                }
            }
            args.push(Arg::Scalar(lcfg.lr));
            args.push(Arg::Scalar(t as f32));
            args.push(Arg::Scalar(qmax_w));
            args.push(Arg::Scalar(qmax_act));

            let outs = eng.run(&art, &args)?;
            losses.push(outs[0].data[0]);
            let n = LINEAR_NAMES.len();
            for (li, name) in LINEAR_NAMES.iter().enumerate() {
                gam.insert(name.to_string(), outs[1 + li].clone());
                bet.insert(name.to_string(), outs[1 + n + li].clone());
                let st =
                    adam.get_mut(*name).expect("adam state exists for every linear name");
                for s in 0..4 {
                    st[s] = outs[1 + (2 + s) * n + li].clone();
                }
            }
        }

        // merge: RTN with learned clips
        let mut block_clips: BlockClips = BTreeMap::new();
        for name in LINEAR_NAMES {
            let w = &bw.linears[name];
            let g = lcfg.qcfg.scheme.group_size(w.shape[1]);
            let gm = gam[name].map(quant::sigmoid);
            let bt = bet[name].map(quant::sigmoid);
            let qp = minmax_scale(
                w,
                g,
                &ClipFactors::PerGroup(gm.clone()),
                &ClipFactors::PerGroup(bt.clone()),
                qmax_w,
            );
            let wq = rtn_qdq(w, &qp, qmax_w);
            params.set_block_linear(l, name, &wq);
            block_clips.insert(name.to_string(), (gm, bt));
        }
        clips_out.push(block_clips);
        losses_out.push(losses);

        let bw_q = params.block(l);
        let prop = if lcfg.propagate_act_quant { qmax_act } else { quant::A16_SENTINEL };
        set.x = runner.forward_all(&bw_q, &set, prop)?;
    }

    Ok(LwcReport { clips: clips_out, losses: losses_out })
}
