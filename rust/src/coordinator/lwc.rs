//! OmniQuant-style Learnable Weight Clipping baseline: block-wise
//! reconstruction over per-group clip logits (gamma, beta) with STE,
//! driven through the `block_lwc_step` artifact. Produces the clip
//! factors TesseraQ uses for its W2A16 initialization (paper §4.1).
//!
//! The block-loop plumbing (teacher targets, checkpoint/resume, stream
//! propagation) lives in [`crate::coordinator::driver`]; this module owns
//! only the LWC math and plugs in as [`LwcOptimizer`]. The learned clip
//! tensors ride along in each checkpoint's `extras`, so a killed LWC run
//! resumes with its clips intact.

use std::collections::BTreeMap;
use std::rc::Rc;

use anyhow::{ensure, Result};

use crate::coordinator::driver::{
    run_guarded, BlockCtx, BlockOptimizer, BlockOutcome, BlockStatus, BlockTrace, CalibReport,
    GuardedIter, IterFailure, ReconstructionDriver,
};
use crate::coordinator::par::BlockClips;
use crate::coordinator::pipeline::CalibSet;
use crate::model::{BlockView, Params, LINEAR_NAMES};
use crate::obs;
use crate::quant::{self, minmax_scale, ClipFactors, QuantConfig};
use crate::robust::{with_retry, BlockCheckpoint, LossHealth, RobustConfig, Sentinel};
use crate::runtime::{Arg, Artifact, Engine};
use crate::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct LwcConfig {
    pub qcfg: QuantConfig,
    pub steps: usize,
    pub lr: f32,
    pub propagate_act_quant: bool,
}

impl LwcConfig {
    pub fn standard(qcfg: QuantConfig) -> Self {
        LwcConfig { qcfg, steps: 120, lr: 5e-2, propagate_act_quant: false }
    }

    pub fn fast(qcfg: QuantConfig) -> Self {
        LwcConfig { steps: 24, ..Self::standard(qcfg) }
    }
}

pub struct LwcReport {
    /// learned per-block clip factors (sigmoid of the raw logits)
    pub clips: Vec<BlockClips>,
    pub losses: Vec<Vec<f32>>,
    /// the driver's full report (traces, codes, fallback blocks)
    pub calib: CalibReport,
}

/// The mutable per-block LWC state: raw clip logits + their Adam moments.
#[derive(Clone)]
pub struct LwcBlockState {
    pub gam: BTreeMap<String, Tensor>,
    pub bet: BTreeMap<String, Tensor>,
    pub adam: BTreeMap<String, [Tensor; 4]>,
}

/// Run LWC calibration in place (weights become fake-quantized) and
/// return the learned clips (reusable as a TesseraQ initializer).
///
/// Thin wrapper over [`calibrate_lwc_robust`] with the default resilience
/// knobs (sentinels + retries on, no checkpointing).
pub fn calibrate_lwc(
    eng: &Engine,
    params: &mut Params,
    tokens: &[i32],
    n_seq: usize,
    lcfg: &LwcConfig,
) -> Result<LwcReport> {
    calibrate_lwc_robust(Some(eng), params, tokens, n_seq, lcfg, &RobustConfig::default())
}

/// Fault-tolerant LWC calibration through the unified
/// [`ReconstructionDriver`]: per-block checkpoint/resume, sentinel
/// rollback on NaN/Inf/divergence in the step loop, retry with host
/// fallback for the forwards. With no engine (or no `block_lwc_step`
/// artifact) every block degrades to RTN with the near-identity initial
/// clips instead of erroring.
pub fn calibrate_lwc_robust(
    eng: Option<&Engine>,
    params: &mut Params,
    tokens: &[i32],
    n_seq: usize,
    lcfg: &LwcConfig,
    robust: &RobustConfig,
) -> Result<LwcReport> {
    // Driver first: it arms the fault plan on the engine before any
    // artifact compile, so compile@ faults reach the optimizer too.
    let driver = ReconstructionDriver::new(eng, robust);
    let size = params.cfg.name.clone();
    let mut opt = LwcOptimizer::new(eng, &size, lcfg, n_seq, robust)?;
    let calib = driver.run(params, &mut opt, tokens, n_seq)?;
    Ok(opt.into_report(calib))
}

/// Like [`calibrate_lwc_robust`] but over a caller-built optimizer —
/// lets tests install a [`LwcOptimizer::step_override`] and inspect the
/// learned clips afterwards.
pub fn calibrate_lwc_with(
    eng: Option<&Engine>,
    params: &mut Params,
    opt: &mut LwcOptimizer,
    tokens: &[i32],
    n_seq: usize,
    robust: &RobustConfig,
) -> Result<CalibReport> {
    let driver = ReconstructionDriver::new(eng, robust);
    driver.run(params, opt, tokens, n_seq)
}

/// OmniQuant-style LWC as a [`BlockOptimizer`].
pub struct LwcOptimizer<'a> {
    lcfg: &'a LwcConfig,
    /// LWC step artifact; unavailable -> RTN with initial clips per block.
    step_art: Option<Rc<Artifact>>,
    batch: usize,
    /// Learned clips per completed block (rebuilt from checkpoint extras
    /// on resume), keyed by layer.
    pub clips: BTreeMap<usize, BlockClips>,
    /// Test hook: a scripted stand-in for the device step, called as
    /// `f(state, t, lr) -> loss` with `t` 1-based. Takes precedence over
    /// the artifact path, letting the sentinel/rollback machinery be
    /// exercised without an engine.
    pub step_override:
        Option<Box<dyn FnMut(&mut LwcBlockState, usize, f32) -> Result<f32> + 'a>>,
}

impl<'a> LwcOptimizer<'a> {
    pub fn new(
        eng: Option<&Engine>,
        size: &str,
        lcfg: &'a LwcConfig,
        n_seq: usize,
        robust: &RobustConfig,
    ) -> Result<LwcOptimizer<'a>> {
        let scheme = lcfg.qcfg.scheme.tag();
        let step_art = eng.and_then(|e| {
            let name = format!("block_lwc_step.{size}.{scheme}");
            match with_retry(&robust.retry, &format!("compiling {name}"), || e.artifact(&name)) {
                Ok(a) => Some(a),
                Err(err) => {
                    obs::warn(
                        "degraded",
                        &format!(
                            "[robust] LWC step artifact unavailable; \
                             degrading to RTN with initial clips per block: {err:#}"
                        ),
                        &[("artifact", name.as_str().into())],
                    );
                    None
                }
            }
        });
        let batch = step_art.as_ref().map_or(1, |a| a.spec.meta.batch.unwrap_or(4));
        if step_art.is_some() {
            ensure!(n_seq % batch == 0, "n_seq {n_seq} not divisible by batch {batch}");
        }
        Ok(LwcOptimizer { lcfg, step_art, batch, clips: BTreeMap::new(), step_override: None })
    }

    /// Consume the optimizer into the public report shape.
    pub fn into_report(self, calib: CalibReport) -> LwcReport {
        let losses = calib.per_block.iter().map(|t| t.losses.clone()).collect();
        LwcReport { clips: self.clips.into_values().collect(), losses, calib }
    }
}

impl BlockOptimizer for LwcOptimizer<'_> {
    fn method_tag(&self) -> &'static str {
        "lwc"
    }

    fn config_string(&self) -> String {
        let c = self.lcfg;
        format!(
            "quant={};steps={};lr={};prop={}",
            c.qcfg.label(),
            c.steps,
            c.lr,
            c.propagate_act_quant
        )
    }

    fn needs_teacher(&self) -> bool {
        // The scripted override ignores the reconstruction target; without
        // a step path every block is RTN and the teacher would be wasted.
        self.step_override.is_none() && self.step_art.is_some()
    }

    fn propagate_qmax(&self) -> f32 {
        if self.lcfg.propagate_act_quant {
            self.lcfg.qcfg.qmax_act()
        } else {
            quant::A16_SENTINEL
        }
    }

    fn optimize_block(&mut self, ctx: &BlockCtx, bw: &BlockView) -> Result<BlockOutcome> {
        let lcfg = self.lcfg;
        let qmax_w = lcfg.qcfg.qmax_w();
        let l = ctx.layer;
        let mut state = init_state(bw, lcfg);
        let mut trace = BlockTrace {
            layer: l,
            losses: Vec::new(),
            flips: BTreeMap::new(),
            initial_loss: f32::NAN,
            status: BlockStatus::Optimized,
        };

        let step = if let Some(f) = self.step_override.as_mut() {
            Some(LwcStepPath::Override(f.as_mut()))
        } else {
            match (ctx.eng, self.step_art.as_deref(), ctx.teacher) {
                (Some(eng), Some(art), Some(teacher)) => {
                    Some(LwcStepPath::Artifact { eng, art, teacher })
                }
                _ => None,
            }
        };
        let fallback_reason = match step {
            Some(step) => {
                let mut lwc = LwcLoop {
                    step,
                    set: ctx.set,
                    bw,
                    batch: self.batch,
                    lcfg,
                    robust: ctx.robust,
                    layer: l,
                    state: &mut state,
                    trace: &mut trace,
                };
                run_guarded(&mut lwc, l, lcfg.steps, ctx.robust.sentinel)?
            }
            None => Some("no LWC step path available".to_string()),
        };

        if let Some(reason) = &fallback_reason {
            obs::warn(
                "fallback",
                &format!("[robust] block {l}: RTN-with-initial-clips fallback ({reason})"),
                &[("layer", l.into()), ("reason", reason.as_str().into())],
            );
            trace.losses.clear();
            trace.initial_loss = 0.0;
            trace.status = BlockStatus::RtnFallback;
            // reset the logits so the merge uses the near-identity clips
            state = init_state(bw, lcfg);
        }

        // merge: RTN with the (learned or initial) clips
        let mut quantized = BTreeMap::new();
        let mut extras = BTreeMap::new();
        let mut block_clips: BlockClips = BTreeMap::new();
        for name in LINEAR_NAMES {
            let w = &bw.linears[name];
            let g = lcfg.qcfg.scheme.group_size(w.shape[1]);
            let gm = state.gam[name].map(quant::sigmoid);
            let bt = state.bet[name].map(quant::sigmoid);
            let qp = minmax_scale(
                w,
                g,
                &ClipFactors::PerGroup(gm.clone()),
                &ClipFactors::PerGroup(bt.clone()),
                qmax_w,
            );
            let codes = quant::rtn_codes(w, &qp, qmax_w);
            trace.flips.insert(name.to_string(), (0, codes.len()));
            extras.insert(format!("gm:{name}"), gm.clone());
            extras.insert(format!("bt:{name}"), bt.clone());
            quantized.insert(name.to_string(), (codes, qp));
            block_clips.insert(name.to_string(), (gm, bt));
        }
        self.clips.insert(l, block_clips);
        Ok(BlockOutcome { trace, quantized, extras })
    }

    fn observe_restored(&mut self, layer: usize, ckpt: &BlockCheckpoint) {
        let mut block_clips: BlockClips = BTreeMap::new();
        for name in LINEAR_NAMES {
            if let (Some(gm), Some(bt)) = (
                ckpt.extras.get(&format!("gm:{name}")),
                ckpt.extras.get(&format!("bt:{name}")),
            ) {
                block_clips.insert(name.to_string(), (gm.clone(), bt.clone()));
            }
        }
        self.clips.insert(layer, block_clips);
    }
}

/// State init: raw logits 4.0 (sigmoid ~ 0.982, near-identity clip).
fn init_state(bw: &BlockView, lcfg: &LwcConfig) -> LwcBlockState {
    let mut gam = BTreeMap::new();
    let mut bet = BTreeMap::new();
    let mut adam = BTreeMap::new();
    for name in LINEAR_NAMES {
        let w = &bw.linears[name];
        let g = lcfg.qcfg.scheme.group_size(w.shape[1]);
        let ng = w.shape[1] / g;
        let shape = vec![w.shape[0], ng];
        gam.insert(name.to_string(), Tensor::full(&shape, 4.0));
        bet.insert(name.to_string(), Tensor::full(&shape, 4.0));
        adam.insert(
            name.to_string(),
            [
                Tensor::zeros(&shape),
                Tensor::zeros(&shape),
                Tensor::zeros(&shape),
                Tensor::zeros(&shape),
            ],
        );
    }
    LwcBlockState { gam, bet, adam }
}

enum LwcStepPath<'a, 'f> {
    Artifact { eng: &'a Engine, art: &'a Artifact, teacher: &'a Tensor },
    Override(&'a mut (dyn FnMut(&mut LwcBlockState, usize, f32) -> Result<f32> + 'f)),
}

/// One LWC block's sentinel-guarded loop; each [`GuardedIter::iteration`]
/// is a single Adam step, so a NaN rolls back exactly one step.
struct LwcLoop<'a, 'f> {
    step: LwcStepPath<'a, 'f>,
    set: &'a CalibSet,
    bw: &'a BlockView,
    batch: usize,
    lcfg: &'a LwcConfig,
    robust: &'a RobustConfig,
    layer: usize,
    state: &'a mut LwcBlockState,
    trace: &'a mut BlockTrace,
}

struct LwcSnapshot {
    state: LwcBlockState,
    n_losses: usize,
    initial_loss: f32,
}

impl GuardedIter for LwcLoop<'_, '_> {
    type Snap = LwcSnapshot;

    fn snapshot(&self) -> LwcSnapshot {
        LwcSnapshot {
            state: self.state.clone(),
            n_losses: self.trace.losses.len(),
            initial_loss: self.trace.initial_loss,
        }
    }

    fn restore(&mut self, snap: &LwcSnapshot) {
        *self.state = snap.state.clone();
        self.trace.losses.truncate(snap.n_losses);
        self.trace.initial_loss = snap.initial_loss;
    }

    fn iteration(&mut self, k: usize, sentinel: &mut Sentinel) -> Result<Option<IterFailure>> {
        let lcfg = self.lcfg;
        let lr = lcfg.lr * sentinel.lr_scale;
        let loss_res = match &mut self.step {
            LwcStepPath::Artifact { eng, art, teacher } => {
                let (eng, art, teacher) = (*eng, *art, *teacher);
                let bi = k - 1;
                let xb = self.set.wrapping_batch(bi, self.batch);
                let yb = self.set.wrapping_slice(teacher, bi, self.batch);
                let bw = self.bw;
                let state = &mut *self.state;
                with_retry(&self.robust.retry, "LWC step", || {
                    lwc_step(eng, art, &xb, &yb, bw, &mut *state, lr, k as f32, lcfg)
                })
            }
            LwcStepPath::Override(f) => f(&mut *self.state, k, lr),
        };
        let mut loss = match loss_res {
            Ok(loss) => loss,
            Err(e) => return Ok(Some(IterFailure::Exec(format!("{e:#}")))),
        };
        if self.robust.faults.as_ref().is_some_and(|p| p.nan_loss(self.layer, k)) {
            loss = f32::NAN;
        }
        match sentinel.observe(loss) {
            LossHealth::Ok => {
                if self.trace.initial_loss.is_nan() {
                    self.trace.initial_loss = loss;
                }
                self.trace.losses.push(loss);
                if obs::enabled() {
                    obs::event(
                        "lwc_iter",
                        &[
                            ("layer", self.layer.into()),
                            ("iter", k.into()),
                            ("loss", loss.into()),
                            ("lr_scale", sentinel.lr_scale.into()),
                        ],
                    );
                }
            }
            LossHealth::NonFinite => {
                return Ok(Some(IterFailure::Numeric(format!("non-finite loss {loss}"))));
            }
            LossHealth::Diverged { baseline } => {
                return Ok(Some(IterFailure::Numeric(format!(
                    "loss {loss:.3e} diverged (baseline {baseline:.3e})"
                ))));
            }
        }
        Ok(None)
    }
}

/// One STE clip-logit Adam step through the artifact; returns the loss
/// and updates the host-side state in place.
fn lwc_step(
    eng: &Engine,
    art: &Artifact,
    xb: &Tensor,
    yb: &Tensor,
    bw: &BlockView,
    state: &mut LwcBlockState,
    lr: f32,
    t: f32,
    lcfg: &LwcConfig,
) -> Result<f32> {
    let mut args: Vec<Arg> =
        vec![Arg::F32(xb), Arg::F32(yb), Arg::F32(&bw.norm1), Arg::F32(&bw.norm2)];
    for name in LINEAR_NAMES {
        args.push(Arg::F32(&bw.linears[name]));
    }
    for name in LINEAR_NAMES {
        args.push(Arg::F32(&state.gam[name]));
    }
    for name in LINEAR_NAMES {
        args.push(Arg::F32(&state.bet[name]));
    }
    for s in 0..4 {
        for name in LINEAR_NAMES {
            args.push(Arg::F32(&state.adam[name][s]));
        }
    }
    args.push(Arg::Scalar(lr));
    args.push(Arg::Scalar(t));
    args.push(Arg::Scalar(lcfg.qcfg.qmax_w()));
    args.push(Arg::Scalar(lcfg.qcfg.qmax_act()));

    let outs = eng.run(art, &args)?;
    let loss = outs[0].data[0];
    let n = LINEAR_NAMES.len();
    for (li, name) in LINEAR_NAMES.iter().enumerate() {
        state.gam.insert(name.to_string(), outs[1 + li].clone());
        state.bet.insert(name.to_string(), outs[1 + n + li].clone());
        let st = state.adam.get_mut(*name).expect("adam state exists for every linear name");
        for s in 0..4 {
            st[s] = outs[1 + (2 + s) * n + li].clone();
        }
    }
    Ok(loss)
}
