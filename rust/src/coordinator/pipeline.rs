//! Shared block-pipeline machinery: batched teacher forwards through the
//! `block_fp_fwd` artifact and calibration-set handling.

use std::rc::Rc;

use anyhow::{ensure, Result};

use crate::model::hostfwd::{block_fwd, BlockFwdOpts};
use crate::model::{BlockView, ModelConfig, Params, LINEAR_NAMES};
use crate::obs;
use crate::robust::{with_retry, RetryPolicy};
use crate::runtime::{Arg, Artifact, Engine};
use crate::tensor::Tensor;

/// A calibration set: `n_seq` sequences of `t` tokens embedded to the
/// residual stream, processed block-by-block.
pub struct CalibSet {
    pub n_seq: usize,
    pub t: usize,
    pub d: usize,
    /// Residual-stream activations at the current block, [n_seq, t, d].
    pub x: Tensor,
}

impl CalibSet {
    pub fn from_tokens(params: &Params, tokens: &[i32], n_seq: usize) -> Result<CalibSet> {
        let cfg = &params.cfg;
        let t = cfg.max_seq;
        ensure!(
            tokens.len() == n_seq * t,
            "calibration token count mismatch: {} tokens for {n_seq} sequences x {t} max_seq",
            tokens.len()
        );
        Ok(CalibSet { n_seq, t, d: cfg.d_model, x: params.embed(tokens, n_seq, t) })
    }

    /// The i-th batch of size b, [b, t, d]; errors past the end.
    pub fn batch(&self, i: usize, b: usize) -> Result<Tensor> {
        ensure!(
            i < self.n_batches(b),
            "batch index {i} out of range ({} batches of {b} over {} sequences)",
            self.n_batches(b),
            self.n_seq
        );
        Ok(self.wrapping_slice(&self.x, i, b))
    }

    /// The (i mod n_batches)-th batch — for optimizer step loops that
    /// deliberately cycle through the calibration set.
    pub fn wrapping_batch(&self, i: usize, b: usize) -> Tensor {
        self.wrapping_slice(&self.x, i, b)
    }

    /// Slice the (i mod n_batches)-th batch out of `y`, any stream-shaped
    /// [n_seq, t, d] tensor (e.g. teacher targets aligned with `x`).
    pub fn wrapping_slice(&self, y: &Tensor, i: usize, b: usize) -> Tensor {
        assert!(b > 0 && self.n_seq % b == 0, "batch {b} must divide n_seq {}", self.n_seq);
        let per = self.t * self.d;
        let idx = i % self.n_batches(b);
        let start = idx * b * per;
        Tensor::new(vec![b, self.t, self.d], y.data[start..start + b * per].to_vec())
    }

    pub fn n_batches(&self, b: usize) -> usize {
        self.n_seq / b
    }

    pub fn write_batch(&mut self, i: usize, b: usize, y: &Tensor) -> Result<()> {
        ensure!(
            i < self.n_batches(b),
            "batch index {i} out of range ({} batches of {b} over {} sequences)",
            self.n_batches(b),
            self.n_seq
        );
        let per = self.t * self.d;
        let start = i * b * per;
        self.x.data[start..start + b * per].copy_from_slice(&y.data);
        Ok(())
    }
}

/// Drives `block_fp_fwd.<size>` over a calibration set in artifact-sized
/// batches. Used for teacher targets AND for propagating the stream
/// through merged (already fake-quantized) blocks.
pub struct BlockRunner<'e> {
    pub eng: &'e Engine,
    pub art: Rc<Artifact>,
    pub batch: usize,
    pub cfg: ModelConfig,
}

impl<'e> BlockRunner<'e> {
    pub fn new(eng: &'e Engine, size: &str) -> Result<Self> {
        let art = eng.artifact(&format!("block_fp_fwd.{size}"))?;
        let batch = art.spec.meta.batch.unwrap_or(art.spec.meta.calib_batch);
        let cfg = ModelConfig::from_meta(&art.spec.meta.model);
        Ok(BlockRunner { eng, art, batch, cfg })
    }

    /// Forward the whole calibration set through one block; returns the
    /// outputs stacked like the input, [n_seq, t, d].
    pub fn forward_all(&self, bw: &BlockView, set: &CalibSet, qmax_act: f32) -> Result<Tensor> {
        ensure!(set.n_seq % self.batch == 0, "n_seq {} % batch {}", set.n_seq, self.batch);
        let mut out = Tensor::zeros(&set.x.shape);
        let per = set.t * set.d * self.batch;
        for i in 0..set.n_batches(self.batch) {
            let xb = set.batch(i, self.batch)?;
            let yb = self.forward_batch(bw, &xb, qmax_act)?;
            out.data[i * per..(i + 1) * per].copy_from_slice(&yb.data);
        }
        Ok(out)
    }

    pub fn forward_batch(&self, bw: &BlockView, xb: &Tensor, qmax_act: f32) -> Result<Tensor> {
        let mut args: Vec<Arg> = vec![Arg::F32(xb), Arg::F32(&bw.norm1), Arg::F32(&bw.norm2)];
        for name in LINEAR_NAMES {
            args.push(Arg::F32(&bw.linears[name]));
        }
        args.push(Arg::Scalar(qmax_act));
        let mut outs = self.eng.run(&self.art, &args)?;
        Ok(outs.remove(0))
    }
}

/// Whole-set block forward on the host (`model/hostfwd.rs`) — the
/// reference path used when no engine is available or the device path
/// persistently fails. `act_fakequant_rows` treats qmax >= 60000 as FP
/// passthrough, matching the artifact's A16 sentinel.
pub fn host_forward_all(
    bw: &BlockView,
    set: &CalibSet,
    cfg: &ModelConfig,
    qmax_act: f32,
) -> Tensor {
    let opts = BlockFwdOpts { act_qmax: Some(qmax_act), collect: false };
    block_fwd(&set.x, bw, cfg, &opts).0
}

/// Forward backend with graceful degradation: the `block_fp_fwd` artifact
/// when an engine is available (with bounded retries), the host-side
/// reference forward otherwise — including when device execution fails
/// persistently mid-run.
pub struct ForwardBackend<'e> {
    runner: Option<BlockRunner<'e>>,
    pub cfg: ModelConfig,
    retry: RetryPolicy,
}

impl<'e> ForwardBackend<'e> {
    pub fn new(
        eng: Option<&'e Engine>,
        cfg: &ModelConfig,
        size: &str,
        retry: &RetryPolicy,
    ) -> ForwardBackend<'e> {
        let runner = eng.and_then(|e| {
            match with_retry(retry, &format!("compiling block_fp_fwd.{size}"), || {
                BlockRunner::new(e, size)
            }) {
                Ok(r) => Some(r),
                Err(err) => {
                    obs::warn(
                        "degraded",
                        &format!(
                            "[robust] block forward artifact unavailable; \
                             using host-side reference forward: {err:#}"
                        ),
                        &[("artifact", format!("block_fp_fwd.{size}").into())],
                    );
                    None
                }
            }
        });
        ForwardBackend { runner, cfg: cfg.clone(), retry: *retry }
    }

    /// True when forwards run on the host fallback path.
    pub fn is_host(&self) -> bool {
        self.runner.is_none()
    }

    pub fn forward_all(&self, bw: &BlockView, set: &CalibSet, qmax_act: f32) -> Result<Tensor> {
        if let Some(r) = &self.runner {
            let what = format!("device forward ({})", r.art.name());
            let t0 = std::time::Instant::now();
            match with_retry(&self.retry, &what, || r.forward_all(bw, set, qmax_act)) {
                Ok(y) => {
                    obs::hist_record("forward.device_us", t0.elapsed().as_secs_f64() * 1e6);
                    obs::counter_add("forward.device", 1);
                    return Ok(y);
                }
                Err(e) => {
                    obs::counter_add("forward.device_failed", 1);
                    obs::warn(
                        "degraded",
                        &format!(
                            "[robust] {what} failed persistently; host-side reference forward: {e:#}"
                        ),
                        &[("what", what.as_str().into())],
                    );
                }
            }
        }
        let t0 = std::time::Instant::now();
        let y = host_forward_all(bw, set, &self.cfg, qmax_act);
        obs::hist_record("forward.host_us", t0.elapsed().as_secs_f64() * 1e6);
        obs::counter_add("forward.host", 1);
        Ok(y)
    }
}
