//! Host-side (pure Rust) decoder forward.
//!
//! Mirrors python/compile/model.py block_core numerically (same RoPE
//! rotate-half convention, same pre-norm topology, same per-token
//! activation fake-quant placement). Used where the AOT artifacts don't
//! fit: GPTQ per-linear input collection (needs intra-block activations)
//! and the packed-weight serving path (incremental decode with KV cache).
//! An integration test ties this forward to the `block_fp_fwd` artifact.

use std::collections::BTreeMap;

use crate::model::{BlockView, ModelConfig};
use crate::quant::act_fakequant_rows;
use crate::tensor::{linalg, Tensor};

/// Anything that can act as `y = x @ W^T` (dense f32, packed INT2/3/4...).
pub trait LinearOp: Sync {
    fn out_features(&self) -> usize;
    fn in_features(&self) -> usize;
    /// x: [rows, in] -> [rows, out]
    fn forward(&self, x: &Tensor) -> Tensor;
    /// `forward` into a caller-provided buffer: x is [m, in] flattened,
    /// `out.len() == m * out_features`. The serving decode loop runs every
    /// linear through this so steady-state decoding allocates nothing; the
    /// default falls back to `forward` for exotic impls.
    fn forward_into(&self, x: &[f32], m: usize, out: &mut [f32]) {
        let y = self.forward(&Tensor::new(vec![m, self.in_features()], x.to_vec()));
        out.copy_from_slice(&y.data);
    }
    /// Weight memory footprint in bytes (Table 8).
    fn weight_bytes(&self) -> usize;
}

impl LinearOp for Tensor {
    fn out_features(&self) -> usize {
        self.shape[0]
    }
    fn in_features(&self) -> usize {
        self.shape[1]
    }
    fn forward(&self, x: &Tensor) -> Tensor {
        linalg::matmul_bt(x, self)
    }
    fn forward_into(&self, x: &[f32], m: usize, out: &mut [f32]) {
        linalg::matmul_bt_into(x, m, self.shape[1], &self.data, self.shape[0], out);
    }
    fn weight_bytes(&self) -> usize {
        // FP16 reference footprint (the paper's FP16 baseline), not f32:
        // our artifacts compute in f32 but the memory comparison in
        // Table 8 is against FP16 storage.
        self.data.len() * 2
    }
}

pub fn rmsnorm_rows(x: &mut [f32], d: usize, w: &[f32], eps: f32) {
    for row in x.chunks_mut(d) {
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + eps).sqrt();
        for (v, &wv) in row.iter_mut().zip(w) {
            *v = *v * r * wv;
        }
    }
}

pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// RoPE rotate-half, matching python _apply_rope: first half paired with
/// second half. q row layout: [head_dim] per (head, position).
fn apply_rope_row(row: &mut [f32], pos: usize, theta: f32) {
    let hd = row.len();
    let half = hd / 2;
    for i in 0..half {
        let inv = 1.0 / theta.powf((2 * i) as f32 / hd as f32);
        let ang = pos as f32 * inv;
        let (s, c) = ang.sin_cos();
        let a = row[i];
        let b = row[i + half];
        row[i] = a * c - b * s;
        row[i + half] = a * s + b * c;
    }
}

/// Per-linear input taps collected during a block forward (GPTQ/AWQ).
pub type Taps = BTreeMap<String, Tensor>;

pub struct BlockFwdOpts {
    /// Per-token activation fake-quant qmax (None = FP activations).
    pub act_qmax: Option<f32>,
    /// Collect per-linear inputs.
    pub collect: bool,
}

impl Default for BlockFwdOpts {
    fn default() -> Self {
        BlockFwdOpts { act_qmax: None, collect: false }
    }
}

/// One decoder block over [b, t, d] input with dense weights.
pub fn block_fwd(
    x: &Tensor,
    bw: &BlockView,
    cfg: &ModelConfig,
    opts: &BlockFwdOpts,
) -> (Tensor, Taps) {
    let lin: BTreeMap<String, &dyn LinearOp> = bw
        .linears
        .iter()
        .map(|(k, v)| (k.clone(), v as &dyn LinearOp))
        .collect();
    block_fwd_ops(x, &lin, &bw.norm1, &bw.norm2, cfg, opts)
}

/// One decoder block with arbitrary LinearOps (dense or packed).
pub fn block_fwd_ops(
    x: &Tensor,
    lin: &BTreeMap<String, &dyn LinearOp>,
    norm1: &Tensor,
    norm2: &Tensor,
    cfg: &ModelConfig,
    opts: &BlockFwdOpts,
) -> (Tensor, Taps) {
    let (b, t, d) = (x.shape[0], x.shape[1], x.shape[2]);
    assert_eq!(d, cfg.d_model);
    let rows = b * t;
    let mut taps = Taps::new();

    let maybe_q = |h: &mut Tensor| {
        if let Some(qmax) = opts.act_qmax {
            act_fakequant_rows(&mut h.data, *h.shape.last().unwrap(), qmax);
        }
    };

    // -- attention ---------------------------------------------------------
    let mut h = Tensor::new(vec![rows, d], x.data.clone());
    rmsnorm_rows(&mut h.data, d, &norm1.data, cfg.norm_eps);
    maybe_q(&mut h);
    if opts.collect {
        taps.insert("qkv_in".into(), h.clone());
    }
    let q = lin["q_proj"].forward(&h);
    let k = lin["k_proj"].forward(&h);
    let v = lin["v_proj"].forward(&h);

    let nh = cfg.n_heads;
    let nkv = cfg.n_kv_heads;
    let hd = cfg.head_dim();
    let rep = nh / nkv;
    let scale = 1.0 / (hd as f32).sqrt();

    let ctx = vec![0.0f32; rows * d];
    // [b, t, nh, hd] view of q; k/v have nkv heads.
    crate::util::parallel_chunks(b * nh, |_, s0, e0| {
        // SAFETY-free approach: compute into local buffer then copy under
        // disjoint indices. ctx is indexed disjointly per (batch, head).
        let ctx_ptr = ctx.as_ptr() as usize;
        for bh in s0..e0 {
            let bi = bh / nh;
            let hi = bh % nh;
            let kvh = hi / rep;
            let mut scores = vec![0.0f32; t];
            for qt in 0..t {
                let qoff = (bi * t + qt) * d + hi * hd;
                let mut qrow = q.data[qoff..qoff + hd].to_vec();
                apply_rope_row(&mut qrow, qt, cfg.rope_theta);
                // causal scores
                let mut maxv = f32::NEG_INFINITY;
                for kt in 0..=qt {
                    let koff = (bi * t + kt) * cfg.d_kv() + kvh * hd;
                    let mut krow = k.data[koff..koff + hd].to_vec();
                    apply_rope_row(&mut krow, kt, cfg.rope_theta);
                    let dot: f32 =
                        qrow.iter().zip(&krow).map(|(a, b)| a * b).sum::<f32>() * scale;
                    scores[kt] = dot;
                    maxv = maxv.max(dot);
                }
                let mut denom = 0.0f32;
                for s in scores[..=qt].iter_mut() {
                    *s = (*s - maxv).exp();
                    denom += *s;
                }
                let out_off = (bi * t + qt) * d + hi * hd;
                let out = unsafe {
                    std::slice::from_raw_parts_mut(
                        (ctx_ptr as *mut f32).add(out_off),
                        hd,
                    )
                };
                for kt in 0..=qt {
                    let w = scores[kt] / denom;
                    let voff = (bi * t + kt) * cfg.d_kv() + kvh * hd;
                    for (o, &vv) in out.iter_mut().zip(&v.data[voff..voff + hd]) {
                        *o += w * vv;
                    }
                }
            }
        }
    });
    let mut ctx = Tensor::new(vec![rows, d], ctx);
    maybe_q(&mut ctx);
    if opts.collect {
        taps.insert("o_in".into(), ctx.clone());
    }
    let attn_out = lin["o_proj"].forward(&ctx);
    let mut x1 = x.data.clone();
    for (a, b) in x1.iter_mut().zip(&attn_out.data) {
        *a += b;
    }

    // -- MLP -----------------------------------------------------------------
    let mut h2 = Tensor::new(vec![rows, d], x1.clone());
    rmsnorm_rows(&mut h2.data, d, &norm2.data, cfg.norm_eps);
    maybe_q(&mut h2);
    if opts.collect {
        taps.insert("mlp_in".into(), h2.clone());
    }
    let gate = lin["gate_proj"].forward(&h2);
    let up = lin["up_proj"].forward(&h2);
    let f = cfg.d_ff;
    let mut mlp = vec![0.0f32; rows * f];
    for i in 0..rows * f {
        mlp[i] = silu(gate.data[i]) * up.data[i];
    }
    let mut mlp = Tensor::new(vec![rows, f], mlp);
    maybe_q(&mut mlp);
    if opts.collect {
        taps.insert("down_in".into(), mlp.clone());
    }
    let down = lin["down_proj"].forward(&mlp);
    for (a, b) in x1.iter_mut().zip(&down.data) {
        *a += b;
    }
    (Tensor::new(vec![b, t, d], x1), taps)
}

/// Map tap names to the linears they feed (paper Table 7 layer naming).
pub fn tap_for_linear(name: &str) -> &'static str {
    match name {
        "q_proj" | "k_proj" | "v_proj" => "qkv_in",
        "o_proj" => "o_in",
        "gate_proj" | "up_proj" => "mlp_in",
        "down_proj" => "down_in",
        _ => panic!("unknown linear {name}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Params;
    use crate::tensor::Pcg32;

    fn setup() -> (ModelConfig, Params, Tensor) {
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(0);
        let p = Params::init(&cfg, &mut rng);
        let x = Tensor::randn(&[2, cfg.max_seq, cfg.d_model], 1.0, &mut rng);
        (cfg, p, x)
    }

    #[test]
    fn block_fwd_shape_and_finite() {
        let (cfg, p, x) = setup();
        let (y, taps) = block_fwd(&x, &p.block(0), &cfg, &BlockFwdOpts::default());
        assert_eq!(y.shape, x.shape);
        assert!(y.data.iter().all(|v| v.is_finite()));
        assert!(taps.is_empty());
    }

    #[test]
    fn collect_taps_shapes() {
        let (cfg, p, x) = setup();
        let opts = BlockFwdOpts { act_qmax: None, collect: true };
        let (_, taps) = block_fwd(&x, &p.block(0), &cfg, &opts);
        assert_eq!(taps["qkv_in"].shape, vec![2 * cfg.max_seq, cfg.d_model]);
        assert_eq!(taps["down_in"].shape, vec![2 * cfg.max_seq, cfg.d_ff]);
    }

    #[test]
    fn causality_on_host() {
        let (cfg, p, _) = setup();
        let mut rng = Pcg32::seeded(9);
        let mut x1 = Tensor::randn(&[1, 8, cfg.d_model], 1.0, &mut rng);
        // pad to max_seq? host fwd supports any t
        let mut x2 = x1.clone();
        // perturb last position only
        let d = cfg.d_model;
        for i in (7 * d)..(8 * d) {
            x2.data[i] += 1.0;
        }
        let (y1, _) = block_fwd(&x1, &p.block(0), &cfg, &BlockFwdOpts::default());
        let (y2, _) = block_fwd(&x2, &p.block(0), &cfg, &BlockFwdOpts::default());
        for i in 0..(7 * d) {
            assert!((y1.data[i] - y2.data[i]).abs() < 1e-5, "position leak at {i}");
        }
        x1.data[0] += 0.0; // silence unused-mut
    }

    #[test]
    fn act_quant_changes_output() {
        let (cfg, p, x) = setup();
        let (y1, _) = block_fwd(&x, &p.block(0), &cfg, &BlockFwdOpts::default());
        let opts = BlockFwdOpts { act_qmax: Some(15.0), collect: false };
        let (y2, _) = block_fwd(&x, &p.block(0), &cfg, &opts);
        assert!(y1.mse(&y2) > 0.0);
    }
}
