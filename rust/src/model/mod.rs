//! Model definition mirror: configs, parameter store, checkpoint I/O and
//! a host-side (pure Rust) forward used by GPTQ input collection and the
//! packed-weight serving path.

pub mod config;
pub mod hostfwd;
pub mod params;
pub mod transform;

pub use config::ModelConfig;
pub use params::{BlockView, Params, LINEAR_NAMES, PARAM_NAMES};
