//! Model configuration (mirror of python/compile/configs.py — the named
//! presets must stay in sync; the manifest is the authoritative source
//! when an Engine is available).

use anyhow::{bail, Result};

use crate::runtime::ModelMeta;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub max_seq: usize,
    pub rope_theta: f32,
    pub norm_eps: f32,
}

impl ModelConfig {
    pub fn preset(name: &str) -> Result<ModelConfig> {
        let (v, d, h, kv, f, l, t) = match name {
            "nano" => (128, 64, 2, 2, 192, 2, 64),
            "tiny" => (256, 256, 4, 4, 768, 6, 128),
            "tiny-gqa" => (256, 256, 4, 2, 896, 6, 128),
            "small" => (512, 384, 6, 6, 1152, 8, 128),
            _ => bail!("unknown model preset {name:?}"),
        };
        Ok(ModelConfig {
            name: name.to_string(),
            vocab_size: v,
            d_model: d,
            n_heads: h,
            n_kv_heads: kv,
            d_ff: f,
            n_layers: l,
            max_seq: t,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        })
    }

    pub fn from_meta(m: &ModelMeta) -> ModelConfig {
        ModelConfig {
            name: m.name.clone(),
            vocab_size: m.vocab_size,
            d_model: m.d_model,
            n_heads: m.n_heads,
            n_kv_heads: m.n_kv_heads,
            d_ff: m.d_ff,
            n_layers: m.n_layers,
            max_seq: m.max_seq,
            rope_theta: m.rope_theta as f32,
            norm_eps: m.norm_eps as f32,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn d_kv(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    /// (out, in) of every quantizable linear in one block, in the paper's
    /// Table 7 order.
    pub fn linear_shapes(&self) -> Vec<(&'static str, (usize, usize))> {
        let (d, dkv, f) = (self.d_model, self.d_kv(), self.d_ff);
        vec![
            ("q_proj", (d, d)),
            ("k_proj", (dkv, d)),
            ("v_proj", (dkv, d)),
            ("o_proj", (d, d)),
            ("gate_proj", (f, d)),
            ("up_proj", (f, d)),
            ("down_proj", (d, f)),
        ]
    }

    pub fn linear_shape(&self, name: &str) -> (usize, usize) {
        self.linear_shapes()
            .into_iter()
            .find(|(n, _)| *n == name)
            .unwrap_or_else(|| panic!("unknown linear {name}"))
            .1
    }

    pub fn param_count(&self) -> usize {
        let per_block: usize =
            self.linear_shapes().iter().map(|(_, (o, i))| o * i).sum::<usize>()
                + 2 * self.d_model;
        self.vocab_size * self.d_model + self.d_model + self.n_layers * per_block
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_parse_and_divide() {
        for name in ["nano", "tiny", "tiny-gqa", "small"] {
            let c = ModelConfig::preset(name).unwrap();
            assert_eq!(c.d_model % c.n_heads, 0);
            assert_eq!(c.n_heads % c.n_kv_heads, 0);
            assert!(c.param_count() > 0);
        }
        assert!(ModelConfig::preset("huge").is_err());
    }

    #[test]
    fn o_proj_is_square() {
        let c = ModelConfig::preset("tiny-gqa").unwrap();
        assert_eq!(c.linear_shape("o_proj"), (256, 256));
        assert_eq!(c.linear_shape("k_proj"), (128, 256));
    }
}
