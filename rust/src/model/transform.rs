//! Equivalence-preserving model transforms: norm folding (prerequisite
//! for rotation/smoothing) and helpers shared by the SmoothQuant / QuaRot
//! implementations in quant/.
//!
//! Conventions: activations are row vectors, linears compute y = x @ W^T
//! with W [out, in]. "Reader" linears consume the residual stream
//! (q/k/v/gate/up), "writer" linears produce it (o/down).

use crate::model::Params;
use crate::tensor::Tensor;

/// Scale the columns (input channels) of W [out, in] by `s`.
pub fn scale_cols(w: &mut Tensor, s: &[f32]) {
    let (o, i) = w.dims2();
    assert_eq!(s.len(), i);
    for r in 0..o {
        for c in 0..i {
            w.data[r * i + c] *= s[c];
        }
    }
}

/// Scale the rows (output channels) of W [out, in] by `s`.
pub fn scale_rows(w: &mut Tensor, s: &[f32]) {
    let (o, i) = w.dims2();
    assert_eq!(s.len(), o);
    for r in 0..o {
        let sv = s[r];
        for c in 0..i {
            w.data[r * i + c] *= sv;
        }
    }
}

/// Fold RMSNorm weights into the reader linears of every block:
/// norm(x) .* n @ W^T == norm(x) @ (W diag(n))^T. Norm weights become 1.
///
/// norm_f is *not* folded here — the model_fwd_nll artifact takes a
/// `head_t` matrix input that carries diag(norm_f) (and the rotation,
/// when QuaRot is active); see quant::rotate.
pub fn fold_norms(params: &mut Params) {
    let n_layers = params.cfg.n_layers;
    for l in 0..n_layers {
        let n1 = params.get("norm1").index0(l);
        let n2 = params.get("norm2").index0(l);
        for name in ["q_proj", "k_proj", "v_proj"] {
            let mut w = params.get(name).index0(l);
            scale_cols(&mut w, &n1.data);
            params.set_block_linear(l, name, &w);
        }
        for name in ["gate_proj", "up_proj"] {
            let mut w = params.get(name).index0(l);
            scale_cols(&mut w, &n2.data);
            params.set_block_linear(l, name, &w);
        }
        let ones = Tensor::full(&[params.cfg.d_model], 1.0);
        params.get_mut("norm1").set_index0(l, &ones);
        params.get_mut("norm2").set_index0(l, &ones);
    }
}

/// head_t for an untransformed model: diag(norm_f), with norm_f set to 1.
pub fn extract_head_t(params: &mut Params) -> Tensor {
    let d = params.cfg.d_model;
    let nf = params.get("norm_f").clone();
    let mut head = Tensor::zeros(&[d, d]);
    for i in 0..d {
        head.data[i * d + i] = nf.data[i];
    }
    params.set("norm_f", Tensor::full(&[d], 1.0));
    head
}

/// Identity head_t (for models evaluated without any transform).
pub fn identity_head_t(d: usize) -> Tensor {
    let mut t = Tensor::zeros(&[d, d]);
    for i in 0..d {
        t.data[i * d + i] = 1.0;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::hostfwd::{block_fwd, BlockFwdOpts};
    use crate::model::{ModelConfig, Params};
    use crate::tensor::{Pcg32, Tensor};

    #[test]
    fn fold_norms_preserves_block_output() {
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(0);
        let mut p = Params::init(&cfg, &mut rng);
        // non-trivial norm weights
        let shape = vec![cfg.n_layers, cfg.d_model];
        p.set("norm1", Tensor::from_fn(&shape, |i| 0.5 + (i % 7) as f32 * 0.2));
        p.set("norm2", Tensor::from_fn(&shape, |i| 0.8 + (i % 5) as f32 * 0.1));
        let x = Tensor::randn(&[1, 16, cfg.d_model], 1.0, &mut rng);
        let (y0, _) = block_fwd(&x, &p.block(0), &cfg, &BlockFwdOpts::default());
        fold_norms(&mut p);
        assert!(p.get("norm1").data.iter().all(|&v| v == 1.0));
        let (y1, _) = block_fwd(&x, &p.block(0), &cfg, &BlockFwdOpts::default());
        assert!(y0.mse(&y1) < 1e-9, "folding changed output: {}", y0.mse(&y1));
    }

    #[test]
    fn head_t_extraction() {
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(1);
        let mut p = Params::init(&cfg, &mut rng);
        let d = cfg.d_model;
        p.set("norm_f", Tensor::from_fn(&[d], |i| 1.0 + i as f32 * 0.01));
        let head = extract_head_t(&mut p);
        assert_eq!(head.shape, vec![d, d]);
        assert!((head.data[0] - 1.0).abs() < 1e-6);
        assert!((head.data[d + 1] - 1.01).abs() < 1e-6);
        assert!(p.get("norm_f").data.iter().all(|&v| v == 1.0));
    }
}
