//! Parameter store: stacked per-name tensors matching the artifact input
//! contract (python/compile/model.py PARAM_NAMES), plus binary checkpoint
//! I/O (own format — offline environment, no external serialization).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::ModelConfig;
use crate::tensor::{Pcg32, Tensor};

pub const LINEAR_NAMES: [&str; 7] = [
    "q_proj", "k_proj", "v_proj", "o_proj", "gate_proj", "up_proj", "down_proj",
];

/// Artifact positional order: emb, norm_f, linears..., norm1, norm2.
pub const PARAM_NAMES: [&str; 11] = [
    "emb", "norm_f", "q_proj", "k_proj", "v_proj", "o_proj", "gate_proj",
    "up_proj", "down_proj", "norm1", "norm2",
];

#[derive(Clone, Debug)]
pub struct Params {
    pub cfg: ModelConfig,
    map: BTreeMap<String, Tensor>,
}

/// Per-block weight view (owned copies of one layer's slices).
#[derive(Clone, Debug)]
pub struct BlockView {
    pub layer: usize,
    pub linears: BTreeMap<String, Tensor>,
    pub norm1: Tensor,
    pub norm2: Tensor,
}

impl Params {
    pub fn shape_of(cfg: &ModelConfig, name: &str) -> Vec<usize> {
        match name {
            "emb" => vec![cfg.vocab_size, cfg.d_model],
            "norm_f" => vec![cfg.d_model],
            "norm1" | "norm2" => vec![cfg.n_layers, cfg.d_model],
            _ => {
                let (o, i) = cfg.linear_shape(name);
                vec![cfg.n_layers, o, i]
            }
        }
    }

    /// Random init matching python/tests conventions: norms = 1, weights
    /// N(0, (0.4/sqrt(fan_in))^2).
    pub fn init(cfg: &ModelConfig, rng: &mut Pcg32) -> Params {
        let mut map = BTreeMap::new();
        for name in PARAM_NAMES {
            let shape = Self::shape_of(cfg, name);
            let t = if name.contains("norm") {
                Tensor::full(&shape, 1.0)
            } else {
                let fan_in = *shape.last().unwrap() as f32;
                Tensor::randn(&shape, 0.4 / fan_in.sqrt(), rng)
            };
            map.insert(name.to_string(), t);
        }
        Params { cfg: cfg.clone(), map }
    }

    pub fn get(&self, name: &str) -> &Tensor {
        self.map.get(name).unwrap_or_else(|| panic!("no param {name}"))
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Tensor {
        self.map.get_mut(name).unwrap_or_else(|| panic!("no param {name}"))
    }

    pub fn set(&mut self, name: &str, t: Tensor) {
        let expect = Self::shape_of(&self.cfg, name);
        assert_eq!(t.shape, expect, "param {name}");
        self.map.insert(name.to_string(), t);
    }

    /// Tensors in artifact positional order.
    pub fn ordered(&self) -> Vec<&Tensor> {
        PARAM_NAMES.iter().map(|n| self.get(n)).collect()
    }

    /// Replace all tensors from artifact-ordered outputs.
    pub fn set_ordered(&mut self, tensors: &[Tensor]) {
        assert_eq!(tensors.len(), PARAM_NAMES.len());
        for (name, t) in PARAM_NAMES.iter().zip(tensors) {
            self.set(name, t.clone());
        }
    }

    /// Zero-initialized clone (Adam state).
    pub fn zeros_like(&self) -> Params {
        let map = self
            .map
            .iter()
            .map(|(k, v)| (k.clone(), Tensor::zeros(&v.shape)))
            .collect();
        Params { cfg: self.cfg.clone(), map }
    }

    pub fn block(&self, layer: usize) -> BlockView {
        assert!(layer < self.cfg.n_layers);
        let mut linears = BTreeMap::new();
        for name in LINEAR_NAMES {
            linears.insert(name.to_string(), self.get(name).index0(layer));
        }
        BlockView {
            layer,
            linears,
            norm1: self.get("norm1").index0(layer),
            norm2: self.get("norm2").index0(layer),
        }
    }

    pub fn set_block_linear(&mut self, layer: usize, name: &str, w: &Tensor) {
        self.get_mut(name).set_index0(layer, w);
    }

    /// Embedding lookup: tokens [b, t] -> activations [b, t, d].
    pub fn embed(&self, tokens: &[i32], b: usize, t: usize) -> Tensor {
        let emb = self.get("emb");
        let d = self.cfg.d_model;
        assert_eq!(tokens.len(), b * t);
        let mut out = vec![0.0f32; b * t * d];
        for (i, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            assert!(tok < self.cfg.vocab_size, "token {tok} out of range");
            out[i * d..(i + 1) * d].copy_from_slice(&emb.data[tok * d..(tok + 1) * d]);
        }
        Tensor::new(vec![b, t, d], out)
    }

    // -- checkpoint I/O ----------------------------------------------------

    const MAGIC: &'static [u8; 4] = b"TSQ1";

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(Self::MAGIC)?;
        write_str(&mut f, &self.cfg.name)?;
        f.write_all(&(self.map.len() as u32).to_le_bytes())?;
        for (name, t) in &self.map {
            write_str(&mut f, name)?;
            f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            let bytes: Vec<u8> = t.data.iter().flat_map(|v| v.to_le_bytes()).collect();
            f.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Params> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != Self::MAGIC {
            bail!("{}: not a TSQ1 checkpoint", path.display());
        }
        let cfg_name = read_str(&mut f)?;
        let cfg = ModelConfig::preset(&cfg_name)?;
        let n = read_u32(&mut f)? as usize;
        let mut map = BTreeMap::new();
        for _ in 0..n {
            let name = read_str(&mut f)?;
            let rank = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u32(&mut f)? as usize);
            }
            let count: usize = shape.iter().product();
            let mut bytes = vec![0u8; count * 4];
            f.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            map.insert(name, Tensor::new(shape, data));
        }
        let p = Params { cfg, map };
        for name in PARAM_NAMES {
            if !p.map.contains_key(name) {
                bail!("checkpoint missing param {name}");
            }
        }
        Ok(p)
    }
}

fn write_str(f: &mut impl Write, s: &str) -> Result<()> {
    f.write_all(&(s.len() as u32).to_le_bytes())?;
    f.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str(f: &mut impl Read) -> Result<String> {
    let n = read_u32(f)? as usize;
    if n > 1 << 16 {
        bail!("string too long ({n})");
    }
    let mut buf = vec![0u8; n];
    f.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_shapes_match_contract() {
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(0);
        let p = Params::init(&cfg, &mut rng);
        assert_eq!(p.get("emb").shape, vec![128, 64]);
        assert_eq!(p.get("down_proj").shape, vec![2, 64, 192]);
        assert_eq!(p.ordered().len(), 11);
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(1);
        let p = Params::init(&cfg, &mut rng);
        let dir = std::env::temp_dir().join("tesseraq_test_ckpt");
        let path = dir.join("nano.tsq");
        p.save(&path).unwrap();
        let q = Params::load(&path).unwrap();
        for name in PARAM_NAMES {
            assert_eq!(p.get(name), q.get(name), "{name}");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn block_view_and_writeback() {
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(2);
        let mut p = Params::init(&cfg, &mut rng);
        let b = p.block(1);
        assert_eq!(b.linears["q_proj"].shape, vec![64, 64]);
        let w = Tensor::full(&[64, 64], 7.0);
        p.set_block_linear(1, "q_proj", &w);
        assert_eq!(p.block(1).linears["q_proj"], w);
        assert_ne!(p.block(0).linears["q_proj"], w);
    }

    #[test]
    fn embed_gathers_rows() {
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(3);
        let p = Params::init(&cfg, &mut rng);
        let x = p.embed(&[5, 9], 1, 2);
        assert_eq!(x.shape, vec![1, 2, 64]);
        assert_eq!(&x.data[..64], &p.get("emb").data[5 * 64..6 * 64]);
    }
}
