//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! Usage from a `harness = false` bench target:
//! ```ignore
//! let mut b = Bench::new("table8");
//! b.iter("fp16 matmul", || { ... });
//! b.report();
//! ```
//! Runs a warmup, then timed batches until `min_time` elapses, and reports
//! mean/p50/p95 per-iteration wall time plus derived throughput.

use std::time::{Duration, Instant};

pub struct Bench {
    pub name: String,
    pub min_time: Duration,
    pub warmup: Duration,
    results: Vec<Record>,
}

#[derive(Clone, Debug)]
pub struct Record {
    pub label: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl Record {
    pub fn mean_s(&self) -> f64 {
        if self.iters == 0 {
            return 0.0;
        }
        self.mean_ns / 1e9
    }
}

impl Bench {
    pub fn new(name: &str) -> Self {
        // Keep CI-ish runs quick but stable; override with env.
        let ms = std::env::var("TESSERAQ_BENCH_MS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(700u64);
        Self::with_min_time(name, Duration::from_millis(ms))
    }

    /// Explicit measurement budget, bypassing `TESSERAQ_BENCH_MS` — for
    /// tests and callers that must not depend on (or mutate) process-wide
    /// environment state.
    pub fn with_min_time(name: &str, min_time: Duration) -> Self {
        Bench {
            name: name.to_string(),
            min_time,
            warmup: min_time / 4,
            results: Vec::new(),
        }
    }

    /// Time a closure; returns the record (also stored for `report`).
    pub fn iter<F: FnMut()>(&mut self, label: &str, mut f: F) -> Record {
        // warmup
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            f();
        }
        let mut samples: Vec<f64> = Vec::new();
        let t1 = Instant::now();
        while t1.elapsed() < self.min_time || samples.len() < 5 {
            let s = Instant::now();
            f();
            samples.push(s.elapsed().as_nanos() as f64);
            if samples.len() > 100_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.total_cmp(b));
        // guard the empty case: mean/quantiles of no samples are 0, not
        // a division by zero / index panic
        let rec = if samples.is_empty() {
            Record {
                label: label.to_string(),
                iters: 0,
                mean_ns: 0.0,
                p50_ns: 0.0,
                p95_ns: 0.0,
            }
        } else {
            Record {
                label: label.to_string(),
                iters: samples.len() as u64,
                mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
                p50_ns: samples[samples.len() / 2],
                // clamp, don't wrap: `(len * 0.95) as usize` == len for
                // small sample counts, and `% len` would alias that to
                // index 0 — reporting the MINIMUM as the p95
                p95_ns: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
            }
        };
        // stderr, not stdout: bench binaries may have their stdout piped
        // into JSON consumers, and progress lines must not corrupt that
        eprintln!(
            "{:<44} {:>10}/iter  p50 {:>10}  p95 {:>10}  ({} iters)",
            format!("{}/{}", self.name, label),
            fmt_ns(rec.mean_ns),
            fmt_ns(rec.p50_ns),
            fmt_ns(rec.p95_ns),
            rec.iters
        );
        crate::obs::event(
            "bench",
            &[
                ("bench", self.name.as_str().into()),
                ("label", rec.label.as_str().into()),
                ("iters", rec.iters.into()),
                ("mean_ns", rec.mean_ns.into()),
                ("p50_ns", rec.p50_ns.into()),
                ("p95_ns", rec.p95_ns.into()),
            ],
        );
        self.results.push(rec.clone());
        rec
    }

    /// Persist the results as a markdown section under results/bench.md
    /// and summarize on stderr (stdout stays clean for piped consumers).
    pub fn report(&self) {
        let mut md = format!("## bench {}\n\n", self.name);
        md.push_str("| case | mean/iter | p50 | p95 | iters |\n");
        md.push_str("| --- | --- | --- | --- | --- |\n");
        for r in &self.results {
            md.push_str(&format!(
                "| {} | {} | {} | {} | {} |\n",
                r.label,
                fmt_ns(r.mean_ns),
                fmt_ns(r.p50_ns),
                fmt_ns(r.p95_ns),
                r.iters
            ));
        }
        if let Err(e) = crate::report::append_log("bench.md", &md) {
            eprintln!("[bench] could not write results/bench.md: {e:#}");
        }
        eprintln!("-- {} done ({} cases)", self.name, self.results.len());
    }

    pub fn results(&self) -> &[Record] {
        &self.results
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        // with_min_time, not set_var: tests run concurrently and mutating
        // TESSERAQ_BENCH_MS would race any other test constructing a Bench
        let mut b = Bench::with_min_time("self", Duration::from_millis(20));
        let rec = b.iter("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(rec.mean_ns > 0.0);
        assert!(rec.iters >= 5);
    }

    #[test]
    fn p95_clamps_to_last_sample() {
        // 5 samples: (5 * 0.95) as usize == 4 == len - 1; anything that
        // wraps (the old `% len`) would report samples[0] (the minimum)
        let mut b = Bench::with_min_time("self", Duration::from_millis(1));
        let rec = b.iter("tiny", || {
            std::hint::black_box(std::hint::black_box(3u64).pow(2));
        });
        assert!(rec.iters >= 5);
        assert!(rec.p95_ns >= rec.p50_ns, "p95 {} < p50 {}", rec.p95_ns, rec.p50_ns);
    }
}
