//! In-tree replacements for crates unavailable in the offline vendor set:
//! JSON parsing, a scoped-thread parallel-for, a micro-bench harness and a
//! tiny seeded property-testing helper.

pub mod bench;
pub mod json;

/// Number of worker threads for host-side parallel loops.
pub fn n_threads() -> usize {
    std::env::var("TESSERAQ_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        })
}

/// Number of workers `parallel_chunks(n, ..)` will actually spawn for `n`
/// items (1 means the serial fallback). Callers that hand each worker a
/// disjoint slice of a preallocated arena (the serving decode scratch)
/// size the arena with this.
pub fn planned_workers(n: usize) -> usize {
    let workers = n_threads().min(n.max(1));
    if workers <= 1 || n < 64 {
        1
    } else {
        workers
    }
}

/// Run `f(chunk_index, start, end)` over `n` items split into contiguous
/// chunks across the thread pool. `f` must be Sync; chunks don't overlap.
pub fn parallel_chunks<F>(n: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let workers = planned_workers(n);
    if workers <= 1 {
        f(0, 0, n);
        return;
    }
    let per = n.div_ceil(workers);
    std::thread::scope(|s| {
        for w in 0..workers {
            let start = w * per;
            let end = ((w + 1) * per).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(w, start, end));
        }
    });
}

/// Parallel map over disjoint mutable row-chunks of `out` (rows of width
/// `width`), calling `f(row_index, row_slice)`.
pub fn parallel_rows<F>(out: &mut [f32], width: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert_eq!(out.len() % width.max(1), 0);
    let rows = if width == 0 { 0 } else { out.len() / width };
    let workers = n_threads().min(rows.max(1));
    if workers <= 1 || rows < 4 {
        for (i, row) in out.chunks_mut(width).enumerate() {
            f(i, row);
        }
        return;
    }
    let per = rows.div_ceil(workers);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut row0 = 0usize;
        for _ in 0..workers {
            let take = per.min(rest.len() / width);
            if take == 0 {
                break;
            }
            let (head, tail) = rest.split_at_mut(take * width);
            rest = tail;
            let f = &f;
            let base = row0;
            s.spawn(move || {
                for (i, row) in head.chunks_mut(width).enumerate() {
                    f(base + i, row);
                }
            });
            row0 += take;
        }
    });
}

/// Elementwise map over `src` across the thread pool, preserving order.
/// Falls back to a serial loop when the input is small or only one worker
/// is configured, so results are identical either way.
pub fn parallel_map<F>(src: &[f32], f: F) -> Vec<f32>
where
    F: Fn(f32) -> f32 + Sync,
{
    let n = src.len();
    let mut out = vec![0.0f32; n];
    let workers = n_threads().min(n.max(1));
    if workers <= 1 || n < 64 {
        for (o, &v) in out.iter_mut().zip(src) {
            *o = f(v);
        }
        return out;
    }
    let per = n.div_ceil(workers);
    std::thread::scope(|s| {
        let mut rest = out.as_mut_slice();
        let mut start = 0usize;
        for _ in 0..workers {
            let take = per.min(rest.len());
            if take == 0 {
                break;
            }
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let f = &f;
            let chunk = &src[start..start + take];
            s.spawn(move || {
                for (o, &v) in head.iter_mut().zip(chunk) {
                    *o = f(v);
                }
            });
            start += take;
        }
    });
    out
}

/// Seeded property-test driver: runs `cases` random cases, reporting the
/// failing seed so a case can be replayed deterministically.
pub fn proptest(cases: usize, base_seed: u64, f: impl Fn(&mut crate::tensor::Pcg32)) {
    for c in 0..cases {
        let seed = base_seed.wrapping_add(c as u64);
        let mut rng = crate::tensor::Pcg32::seeded(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!("property failed at case {c} (seed {seed})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parallel_chunks_covers_everything() {
        let hits = AtomicUsize::new(0);
        parallel_chunks(1000, |_, s, e| {
            hits.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn parallel_rows_writes_disjoint() {
        let mut out = vec![0.0f32; 64 * 8];
        parallel_rows(&mut out, 8, |i, row| {
            for v in row.iter_mut() {
                *v = i as f32;
            }
        });
        for (i, row) in out.chunks(8).enumerate() {
            assert!(row.iter().all(|&v| v == i as f32));
        }
    }

    #[test]
    fn parallel_map_matches_serial() {
        // large enough to take the threaded path, odd length to exercise
        // the final ragged chunk
        let src: Vec<f32> = (0..100_001).map(|i| i as f32 * 0.25 - 7.0).collect();
        let got = parallel_map(&src, |x| x * x + 1.0);
        for (i, (&g, &s)) in got.iter().zip(&src).enumerate() {
            assert_eq!(g, s * s + 1.0, "elem {i}");
        }
        assert!(parallel_map(&[], |x| x).is_empty());
        assert_eq!(parallel_map(&[2.0], |x| x * 3.0), vec![6.0]);
    }

    #[test]
    fn proptest_reports_seed() {
        // must pass for all seeds
        proptest(16, 42, |rng| {
            let x = rng.uniform();
            assert!((0.0..1.0).contains(&x));
        });
    }
}
