//! Minimal JSON parser (objects/arrays/strings/numbers/bools/null).
//!
//! The environment is offline (no serde_json in the vendor set), and the
//! only JSON we consume is the artifact manifest we emit ourselves, so a
//! small recursive-descent parser is the right tool.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).with_context(|| format!("missing key {key:?}")),
            _ => bail!("not an object (looking for {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key).filter(|v| !matches!(v, Json::Null)),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    /// Serialize to compact JSON text. Non-finite numbers become `null`
    /// (JSON has no NaN/Inf), so `dump` output always re-parses.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.dump_into(&mut out);
        out
    }

    fn dump_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    out.push_str(&format!("{n}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                out.push_str(&escape(s));
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.dump_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('"');
                    out.push_str(&escape(k));
                    out.push_str("\":");
                    v.dump_into(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().context("unexpected end of JSON")
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            let v = self.value()?;
            map.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}' at offset {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at offset {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).context("bad \\u escape")?);
                        }
                        _ => bail!("bad escape at offset {}", self.i),
                    }
                }
                _ => s.push(c as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number {s:?}"))?))
    }
}

/// Escape a string for JSON output (used by the results writers).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_structure() {
        let text = r#"{"artifacts": [{"name": "a.b", "inputs": [{"shape": [2, 3], "dtype": "float32"}], "meta": {"sat_nu": 100.0, "scheme": null}}], "n": -1.5e2}"#;
        let j = Json::parse(text).unwrap();
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str().unwrap(), "a.b");
        let sh = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(sh[0].as_usize().unwrap(), 2);
        assert_eq!(j.get("n").unwrap().as_f64().unwrap(), -150.0);
        assert!(arts[0].get("meta").unwrap().opt("scheme").is_none());
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\n\"bA""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\"bA");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn dump_roundtrips() {
        let text = r#"{"a":[1,2.5,null,true],"b":{"c":"x\ny"},"d":-0.125}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
        // non-finite numbers degrade to null, stay parseable
        let bad = Json::Num(f64::NAN);
        assert_eq!(bad.dump(), "null");
        assert_eq!(Json::parse(&bad.dump()).unwrap(), Json::Null);
    }

    #[test]
    fn escape_roundtrip() {
        let s = "line\n\"quoted\"\tend";
        let j = Json::parse(&format!("\"{}\"", escape(s))).unwrap();
        assert_eq!(j.as_str().unwrap(), s);
    }
}
