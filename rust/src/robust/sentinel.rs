//! Numerical sentinels for the soften loop: detect NaN/Inf losses and
//! runaway divergence, and account for the rollback/retry budget.
//!
//! The sentinel itself is engine-agnostic — it only sees the per-step
//! reconstruction loss. The calibration loop owns the actual rollback
//! (restoring nu/v/Adam snapshots); `Sentinel` decides *when* to roll
//! back and what learning-rate scale to retry with.

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SentinelConfig {
    pub enabled: bool,
    /// Rollback/retry budget per block before falling back to RTN.
    pub max_retries: u32,
    /// Learning-rate multiplier applied on each retry (compounding).
    pub lr_backoff: f32,
    /// A finite loss above `divergence_factor * best_loss_so_far` counts
    /// as divergence.
    pub divergence_factor: f32,
}

impl Default for SentinelConfig {
    fn default() -> Self {
        SentinelConfig {
            enabled: true,
            max_retries: 2,
            lr_backoff: 0.5,
            divergence_factor: 1e4,
        }
    }
}

impl SentinelConfig {
    pub fn disabled() -> Self {
        SentinelConfig { enabled: false, ..Default::default() }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LossHealth {
    Ok,
    NonFinite,
    /// Finite but exploded relative to the block's best loss.
    Diverged { baseline: f32 },
}

impl LossHealth {
    pub fn is_ok(&self) -> bool {
        matches!(self, LossHealth::Ok)
    }
}

/// Per-block sentinel state. Create one per block; `lr_scale` persists
/// across retries so a backed-off learning rate stays backed off.
#[derive(Debug)]
pub struct Sentinel {
    cfg: SentinelConfig,
    /// Best (lowest) healthy loss seen so far; NAN until the first one.
    best: f32,
    retries_used: u32,
    pub lr_scale: f32,
}

impl Sentinel {
    pub fn new(cfg: SentinelConfig) -> Sentinel {
        Sentinel { cfg, best: f32::NAN, retries_used: 0, lr_scale: 1.0 }
    }

    /// Classify one step's loss. Healthy losses tighten the divergence
    /// baseline; unhealthy ones leave all state untouched (the caller
    /// decides whether to `trip`).
    pub fn observe(&mut self, loss: f32) -> LossHealth {
        if !self.cfg.enabled {
            return LossHealth::Ok;
        }
        if !loss.is_finite() {
            return LossHealth::NonFinite;
        }
        if !self.best.is_nan() {
            let baseline = self.best.max(f32::MIN_POSITIVE);
            if loss > self.cfg.divergence_factor * baseline {
                return LossHealth::Diverged { baseline };
            }
        }
        if self.best.is_nan() || loss < self.best {
            self.best = loss;
        }
        LossHealth::Ok
    }

    /// Consume one retry. Returns the new lr scale to retry with, or
    /// `None` when the budget is exhausted (caller falls back to RTN).
    pub fn trip(&mut self) -> Option<f32> {
        if self.retries_used >= self.cfg.max_retries {
            return None;
        }
        self.retries_used += 1;
        self.lr_scale *= self.cfg.lr_backoff;
        Some(self.lr_scale)
    }

    pub fn retries_used(&self) -> u32 {
        self.retries_used
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_non_finite() {
        let mut s = Sentinel::new(SentinelConfig::default());
        assert_eq!(s.observe(1.0), LossHealth::Ok);
        assert_eq!(s.observe(f32::NAN), LossHealth::NonFinite);
        assert_eq!(s.observe(f32::INFINITY), LossHealth::NonFinite);
        // healthy state was not poisoned by the bad observations
        assert_eq!(s.observe(0.5), LossHealth::Ok);
    }

    #[test]
    fn flags_divergence_against_best() {
        let mut s = Sentinel::new(SentinelConfig {
            divergence_factor: 100.0,
            ..Default::default()
        });
        // no baseline yet: any finite first loss is accepted
        assert_eq!(s.observe(1e30), LossHealth::Ok);
        assert_eq!(s.observe(0.01), LossHealth::Ok);
        match s.observe(2.0) {
            LossHealth::Diverged { baseline } => assert!((baseline - 0.01).abs() < 1e-9),
            h => panic!("expected divergence, got {h:?}"),
        }
        // just under the factor is fine
        assert_eq!(s.observe(0.9), LossHealth::Ok);
    }

    #[test]
    fn retry_budget_and_backoff() {
        let mut s = Sentinel::new(SentinelConfig {
            max_retries: 2,
            lr_backoff: 0.5,
            ..Default::default()
        });
        assert_eq!(s.trip(), Some(0.5));
        assert_eq!(s.trip(), Some(0.25));
        assert_eq!(s.trip(), None);
        assert_eq!(s.retries_used(), 2);
        assert!((s.lr_scale - 0.25).abs() < 1e-9, "scale persists after exhaustion");
    }

    #[test]
    fn disabled_sentinel_accepts_anything() {
        let mut s = Sentinel::new(SentinelConfig::disabled());
        assert_eq!(s.observe(f32::NAN), LossHealth::Ok);
        assert_eq!(s.observe(f32::INFINITY), LossHealth::Ok);
    }
}
