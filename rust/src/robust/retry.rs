//! Bounded retry with exponential backoff for transient runtime faults
//! (artifact compile, device execute, checkpoint I/O).

use anyhow::{Context, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts including the first (1 = no retry).
    pub max_attempts: u32,
    pub base_delay_ms: u64,
    pub max_delay_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 3, base_delay_ms: 10, max_delay_ms: 200 }
    }
}

impl RetryPolicy {
    /// No retries at all — fail on the first error.
    pub fn none() -> Self {
        RetryPolicy { max_attempts: 1, base_delay_ms: 0, max_delay_ms: 0 }
    }

    /// Retry without sleeping (tests).
    pub fn immediate(max_attempts: u32) -> Self {
        RetryPolicy { max_attempts, base_delay_ms: 0, max_delay_ms: 0 }
    }

    /// Backoff delay before attempt `attempt + 1` (0-indexed failures).
    pub fn delay_ms(&self, failures: u32) -> u64 {
        if self.base_delay_ms == 0 {
            return 0;
        }
        let shift = failures.min(16);
        (self.base_delay_ms.saturating_mul(1u64 << shift)).min(self.max_delay_ms)
    }
}

/// Run `f` under the policy. Failed attempts are logged to stderr with the
/// attempt count; the final error carries a "giving up" context.
pub fn with_retry<T>(
    policy: &RetryPolicy,
    what: &str,
    mut f: impl FnMut() -> Result<T>,
) -> Result<T> {
    let attempts = policy.max_attempts.max(1);
    let mut last_err = None;
    for attempt in 1..=attempts {
        match f() {
            Ok(v) => {
                if attempt > 1 {
                    crate::obs::warn(
                        "retry_recovered",
                        &format!("[robust] {what}: recovered on attempt {attempt}/{attempts}"),
                        &[("what", what.into()), ("attempt", attempt.into())],
                    );
                }
                return Ok(v);
            }
            Err(e) => {
                crate::obs::warn(
                    "retry",
                    &format!("[robust] {what} failed (attempt {attempt}/{attempts}): {e:#}"),
                    &[("what", what.into()), ("attempt", attempt.into())],
                );
                last_err = Some(e);
                if attempt < attempts {
                    let d = policy.delay_ms(attempt - 1);
                    if d > 0 {
                        std::thread::sleep(std::time::Duration::from_millis(d));
                    }
                }
            }
        }
    }
    Err(last_err.unwrap_or_else(|| anyhow::anyhow!("{what}: no attempts ran")))
        .with_context(|| format!("{what}: giving up after {attempts} attempts"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;

    #[test]
    fn succeeds_after_transient_failures() {
        let calls = Cell::new(0u32);
        let out = with_retry(&RetryPolicy::immediate(3), "flaky", || {
            calls.set(calls.get() + 1);
            if calls.get() < 3 {
                anyhow::bail!("transient");
            }
            Ok(42)
        });
        assert_eq!(out.unwrap(), 42);
        assert_eq!(calls.get(), 3);
    }

    #[test]
    fn gives_up_after_max_attempts() {
        let calls = Cell::new(0u32);
        let out: anyhow::Result<()> = with_retry(&RetryPolicy::immediate(4), "doomed", || {
            calls.set(calls.get() + 1);
            anyhow::bail!("persistent")
        });
        let err = format!("{:#}", out.unwrap_err());
        assert_eq!(calls.get(), 4);
        assert!(err.contains("giving up after 4 attempts"), "{err}");
        assert!(err.contains("persistent"), "{err}");
    }

    #[test]
    fn delays_are_bounded() {
        let p = RetryPolicy { max_attempts: 10, base_delay_ms: 10, max_delay_ms: 80 };
        assert_eq!(p.delay_ms(0), 10);
        assert_eq!(p.delay_ms(1), 20);
        assert_eq!(p.delay_ms(2), 40);
        assert_eq!(p.delay_ms(3), 80);
        assert_eq!(p.delay_ms(9), 80);
        assert_eq!(RetryPolicy::immediate(3).delay_ms(5), 0);
    }
}
