//! Deterministic fault injection for resilience testing.
//!
//! A `FaultPlan` is a list of injection sites parsed from a compact spec
//! string (CLI `--inject-faults` or the `TESSERAQ_FAULTS` env var):
//!
//! ```text
//!   nan@<block>.<step>        NaN loss at soften step <step> (1-based,
//!                             global within the block) of block <block>
//!   compile@<substr>[:<n>]    fail artifact compiles whose name contains
//!                             <substr>; <n> times (default: persistent)
//!   exec@<substr>[:<n>]       same for artifact execution
//!   kill@<block>              simulated crash right after block <block>'s
//!                             checkpoint is persisted
//! ```
//!
//! Entries are comma-separated, e.g.
//! `nan@0.3,compile@block_par_step:2,kill@1`. Counters live in `Cell`s so
//! a shared `Rc<FaultPlan>` can be consulted from both the engine and the
//! calibration loop.

use std::cell::Cell;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    NanLoss,
    CompileFail,
    ExecFail,
    Kill,
}

#[derive(Debug)]
struct Site {
    kind: Kind,
    /// Block index for NanLoss/Kill.
    block: usize,
    /// 1-based soften step for NanLoss.
    step: usize,
    /// Artifact-name substring for CompileFail/ExecFail.
    name: String,
    /// Remaining firings; `None` = persistent (never exhausted).
    remaining: Cell<Option<u32>>,
}

impl Site {
    fn take(&self) -> bool {
        match self.remaining.get() {
            None => true,
            Some(0) => false,
            Some(n) => {
                self.remaining.set(Some(n - 1));
                true
            }
        }
    }
}

#[derive(Debug, Default)]
pub struct FaultPlan {
    sites: Vec<Site>,
}

impl FaultPlan {
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut sites = Vec::new();
        for raw in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (kind_s, rest) = raw
                .split_once('@')
                .with_context(|| format!("fault entry {raw:?}: expected <kind>@<site>"))?;
            let site = match kind_s {
                "nan" => {
                    let (b, s) = rest.split_once('.').with_context(|| {
                        format!("fault entry {raw:?}: nan wants <block>.<step>")
                    })?;
                    Site {
                        kind: Kind::NanLoss,
                        block: b.parse().with_context(|| format!("bad block in {raw:?}"))?,
                        step: s.parse().with_context(|| format!("bad step in {raw:?}"))?,
                        name: String::new(),
                        remaining: Cell::new(Some(1)),
                    }
                }
                "compile" | "exec" => {
                    let (name, remaining) = match rest.rsplit_once(':') {
                        Some((n, cnt)) => {
                            let c: u32 = cnt
                                .parse()
                                .with_context(|| format!("bad count in {raw:?}"))?;
                            (n.to_string(), Some(c))
                        }
                        None => (rest.to_string(), None),
                    };
                    if name.is_empty() {
                        bail!("fault entry {raw:?}: empty artifact pattern");
                    }
                    Site {
                        kind: if kind_s == "compile" { Kind::CompileFail } else { Kind::ExecFail },
                        block: 0,
                        step: 0,
                        name,
                        remaining: Cell::new(remaining),
                    }
                }
                "kill" => Site {
                    kind: Kind::Kill,
                    block: rest.parse().with_context(|| format!("bad block in {raw:?}"))?,
                    step: 0,
                    name: String::new(),
                    remaining: Cell::new(Some(1)),
                },
                other => bail!("unknown fault kind {other:?} in {raw:?} (want nan|compile|exec|kill)"),
            };
            sites.push(site);
        }
        if sites.is_empty() {
            bail!("empty fault spec");
        }
        Ok(FaultPlan { sites })
    }

    /// Plan from `TESSERAQ_FAULTS`, if set. A malformed spec is a hard
    /// error on stderr but is otherwise ignored (never poison startup).
    pub fn from_env() -> Option<Rc<FaultPlan>> {
        let spec = std::env::var("TESSERAQ_FAULTS").ok()?;
        match FaultPlan::parse(&spec) {
            Ok(p) => Some(Rc::new(p)),
            Err(e) => {
                eprintln!("[robust] ignoring malformed TESSERAQ_FAULTS={spec:?}: {e:#}");
                None
            }
        }
    }

    fn fire(&self, kind: Kind, block: usize, step: usize, name: &str) -> bool {
        let fired = self.sites.iter().any(|s| {
            s.kind == kind
                && match kind {
                    Kind::NanLoss => s.block == block && s.step == step,
                    Kind::Kill => s.block == block,
                    Kind::CompileFail | Kind::ExecFail => name.contains(&s.name),
                }
                && s.take()
        });
        if fired {
            let tag = match kind {
                Kind::NanLoss => "nan",
                Kind::CompileFail => "compile",
                Kind::ExecFail => "exec",
                Kind::Kill => "kill",
            };
            crate::obs::event(
                "fault_injected",
                &[
                    ("fault", tag.into()),
                    ("block", block.into()),
                    ("step", step.into()),
                    ("artifact", name.into()),
                ],
            );
        }
        fired
    }

    /// Should the soften loss of (block, 1-based step) be corrupted to NaN?
    pub fn nan_loss(&self, block: usize, step: usize) -> bool {
        self.fire(Kind::NanLoss, block, step, "")
    }

    /// Injected compile failure for this artifact name, if scheduled.
    pub fn fail_compile(&self, name: &str) -> Option<anyhow::Error> {
        self.fire(Kind::CompileFail, 0, 0, name)
            .then(|| anyhow::anyhow!("injected compile failure for {name:?}"))
    }

    /// Injected execute failure for this artifact name, if scheduled.
    pub fn fail_exec(&self, name: &str) -> Option<anyhow::Error> {
        self.fire(Kind::ExecFail, 0, 0, name)
            .then(|| anyhow::anyhow!("injected exec failure for {name:?}"))
    }

    /// Simulated crash after `block`'s checkpoint was persisted.
    pub fn kill_after_block(&self, block: usize) -> bool {
        self.fire(Kind::Kill, block, 0, "")
    }
}

/// Error message marker for simulated mid-run kills; tests match on it.
pub const KILL_MARKER: &str = "simulated crash (fault injection)";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_grammar() {
        let p = FaultPlan::parse("nan@0.3, compile@block_par_step:2, exec@fwd, kill@1").unwrap();
        assert_eq!(p.sites.len(), 4);
        // nan fires exactly once at the right site
        assert!(!p.nan_loss(0, 2));
        assert!(!p.nan_loss(1, 3));
        assert!(p.nan_loss(0, 3));
        assert!(!p.nan_loss(0, 3), "nan site must be one-shot");
        // compile fails twice then recovers
        assert!(p.fail_compile("block_par_step.nano.g32").is_some());
        assert!(p.fail_compile("block_par_step.nano.g32").is_some());
        assert!(p.fail_compile("block_par_step.nano.g32").is_none());
        assert!(p.fail_compile("unrelated").is_none());
        // exec is persistent
        for _ in 0..5 {
            assert!(p.fail_exec("block_fp_fwd.nano").is_some());
        }
        // kill fires once
        assert!(!p.kill_after_block(0));
        assert!(p.kill_after_block(1));
        assert!(!p.kill_after_block(1));
    }

    #[test]
    fn rejects_garbage() {
        assert!(FaultPlan::parse("").is_err());
        assert!(FaultPlan::parse("nan@x.y").is_err());
        assert!(FaultPlan::parse("explode@0").is_err());
        assert!(FaultPlan::parse("compile@:3").is_err());
        assert!(FaultPlan::parse("nan@3").is_err());
    }
}
