//! Deterministic fault injection for resilience testing.
//!
//! A `FaultPlan` is a list of injection sites parsed from a compact spec
//! string (CLI `--inject-faults` or the `TESSERAQ_FAULTS` env var):
//!
//! ```text
//!   nan@<block>.<step>        NaN loss at soften step <step> (1-based,
//!                             global within the block) of block <block>
//!   compile@<substr>[:<n>]    fail artifact compiles whose name contains
//!                             <substr>; <n> times (default: persistent)
//!   exec@<substr>[:<n>]       same for artifact execution
//!   kill@<block>              simulated crash right after block <block>'s
//!                             checkpoint is persisted; for the serving
//!                             gateway, <block> is the global decode step
//!                             at which the session aborts
//!   slow@<step>.<ms>          gateway decode step <step> (1-based, global)
//!                             takes an extra <ms> of synthetic time
//!   poison@<req>.<step>       non-finite logits for request id <req> at its
//!                             own 1-based step <step> (prefill included)
//!   stall@<iter>.<ms>         gateway pump iteration <iter> stalls for
//!                             <ms> of synthetic time before dispatch
//! ```
//!
//! Entries are comma-separated, e.g.
//! `nan@0.3,compile@block_par_step:2,kill@1`. Counters live in `Cell`s so
//! a shared `Rc<FaultPlan>` can be consulted from both the engine and the
//! calibration loop. The request-level kinds (`slow`/`poison`/`stall`)
//! advance the gateway's *synthetic* clock rather than sleeping, so chaos
//! drills are deterministic and immune to scheduler jitter.

use std::cell::Cell;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    NanLoss,
    CompileFail,
    ExecFail,
    Kill,
    SlowStep,
    PoisonLogits,
    QueueStall,
}

#[derive(Debug)]
struct Site {
    kind: Kind,
    /// Block index for NanLoss/Kill.
    block: usize,
    /// 1-based soften step for NanLoss.
    step: usize,
    /// Artifact-name substring for CompileFail/ExecFail.
    name: String,
    /// Synthetic delay for SlowStep/QueueStall.
    ms: u64,
    /// Remaining firings; `None` = persistent (never exhausted).
    remaining: Cell<Option<u32>>,
}

impl Site {
    fn take(&self) -> bool {
        match self.remaining.get() {
            None => true,
            Some(0) => false,
            Some(n) => {
                self.remaining.set(Some(n - 1));
                true
            }
        }
    }
}

#[derive(Debug, Default)]
pub struct FaultPlan {
    sites: Vec<Site>,
}

impl FaultPlan {
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut sites = Vec::new();
        for raw in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (kind_s, rest) = raw
                .split_once('@')
                .with_context(|| format!("fault entry {raw:?}: expected <kind>@<site>"))?;
            let site = match kind_s {
                "nan" => {
                    let (b, s) = rest.split_once('.').with_context(|| {
                        format!("fault entry {raw:?}: nan wants <block>.<step>")
                    })?;
                    Site {
                        kind: Kind::NanLoss,
                        block: b.parse().with_context(|| format!("bad block in {raw:?}"))?,
                        step: s.parse().with_context(|| format!("bad step in {raw:?}"))?,
                        name: String::new(),
                        ms: 0,
                        remaining: Cell::new(Some(1)),
                    }
                }
                "slow" | "stall" => {
                    let (at, ms) = rest.split_once('.').with_context(|| {
                        format!("fault entry {raw:?}: {kind_s} wants <at>.<ms>")
                    })?;
                    Site {
                        kind: if kind_s == "slow" { Kind::SlowStep } else { Kind::QueueStall },
                        block: at.parse().with_context(|| format!("bad site in {raw:?}"))?,
                        step: 0,
                        name: String::new(),
                        ms: ms.parse().with_context(|| format!("bad ms in {raw:?}"))?,
                        remaining: Cell::new(Some(1)),
                    }
                }
                "poison" => {
                    let (req, s) = rest.split_once('.').with_context(|| {
                        format!("fault entry {raw:?}: poison wants <req>.<step>")
                    })?;
                    Site {
                        kind: Kind::PoisonLogits,
                        block: req.parse().with_context(|| format!("bad request in {raw:?}"))?,
                        step: s.parse().with_context(|| format!("bad step in {raw:?}"))?,
                        name: String::new(),
                        ms: 0,
                        remaining: Cell::new(Some(1)),
                    }
                }
                "compile" | "exec" => {
                    let (name, remaining) = match rest.rsplit_once(':') {
                        Some((n, cnt)) => {
                            let c: u32 = cnt
                                .parse()
                                .with_context(|| format!("bad count in {raw:?}"))?;
                            (n.to_string(), Some(c))
                        }
                        None => (rest.to_string(), None),
                    };
                    if name.is_empty() {
                        bail!("fault entry {raw:?}: empty artifact pattern");
                    }
                    Site {
                        kind: if kind_s == "compile" { Kind::CompileFail } else { Kind::ExecFail },
                        block: 0,
                        step: 0,
                        name,
                        ms: 0,
                        remaining: Cell::new(remaining),
                    }
                }
                "kill" => Site {
                    kind: Kind::Kill,
                    block: rest.parse().with_context(|| format!("bad block in {raw:?}"))?,
                    step: 0,
                    name: String::new(),
                    ms: 0,
                    remaining: Cell::new(Some(1)),
                },
                other => bail!(
                    "unknown fault kind {other:?} in {raw:?} \
                     (want nan|compile|exec|kill|slow|poison|stall)"
                ),
            };
            sites.push(site);
        }
        if sites.is_empty() {
            bail!("empty fault spec");
        }
        Ok(FaultPlan { sites })
    }

    /// Plan from `TESSERAQ_FAULTS`, if set. A malformed spec is a hard
    /// error on stderr but is otherwise ignored (never poison startup).
    pub fn from_env() -> Option<Rc<FaultPlan>> {
        let spec = std::env::var("TESSERAQ_FAULTS").ok()?;
        match FaultPlan::parse(&spec) {
            Ok(p) => Some(Rc::new(p)),
            Err(e) => {
                crate::obs::warn(
                    "fault_spec_invalid",
                    &format!("[robust] ignoring malformed TESSERAQ_FAULTS={spec:?}: {e:#}"),
                    &[("spec", spec.as_str().into()), ("error", format!("{e:#}").into())],
                );
                None
            }
        }
    }

    fn fire_site(&self, kind: Kind, block: usize, step: usize, name: &str) -> Option<&Site> {
        let site = self.sites.iter().find(|s| {
            s.kind == kind
                && match kind {
                    Kind::NanLoss | Kind::PoisonLogits => s.block == block && s.step == step,
                    Kind::Kill | Kind::SlowStep | Kind::QueueStall => s.block == block,
                    Kind::CompileFail | Kind::ExecFail => name.contains(&s.name),
                }
                && s.take()
        });
        if let Some(s) = site {
            let tag = match kind {
                Kind::NanLoss => "nan",
                Kind::CompileFail => "compile",
                Kind::ExecFail => "exec",
                Kind::Kill => "kill",
                Kind::SlowStep => "slow",
                Kind::PoisonLogits => "poison",
                Kind::QueueStall => "stall",
            };
            crate::obs::event(
                "fault_injected",
                &[
                    ("fault", tag.into()),
                    ("block", block.into()),
                    ("step", step.into()),
                    ("artifact", name.into()),
                    ("ms", s.ms.into()),
                ],
            );
        }
        site
    }

    fn fire(&self, kind: Kind, block: usize, step: usize, name: &str) -> bool {
        self.fire_site(kind, block, step, name).is_some()
    }

    /// Should the soften loss of (block, 1-based step) be corrupted to NaN?
    pub fn nan_loss(&self, block: usize, step: usize) -> bool {
        self.fire(Kind::NanLoss, block, step, "")
    }

    /// Injected compile failure for this artifact name, if scheduled.
    pub fn fail_compile(&self, name: &str) -> Option<anyhow::Error> {
        self.fire(Kind::CompileFail, 0, 0, name)
            .then(|| anyhow::anyhow!("injected compile failure for {name:?}"))
    }

    /// Injected execute failure for this artifact name, if scheduled.
    pub fn fail_exec(&self, name: &str) -> Option<anyhow::Error> {
        self.fire(Kind::ExecFail, 0, 0, name)
            .then(|| anyhow::anyhow!("injected exec failure for {name:?}"))
    }

    /// Simulated crash after `block`'s checkpoint was persisted.
    pub fn kill_after_block(&self, block: usize) -> bool {
        self.fire(Kind::Kill, block, 0, "")
    }

    /// Gateway: simulated engine crash at global decode step `step`
    /// (same `kill@<n>` grammar, reinterpreted on the serving path).
    pub fn kill_at_step(&self, step: usize) -> bool {
        self.fire(Kind::Kill, step, 0, "")
    }

    /// Gateway: synthetic extra latency for global decode step `step`.
    pub fn slow_step(&self, step: usize) -> Option<u64> {
        self.fire_site(Kind::SlowStep, step, 0, "").map(|s| s.ms)
    }

    /// Gateway: poison request `req`'s logits at its own 1-based step.
    pub fn poison_logits(&self, req: u64, step: usize) -> bool {
        let Ok(req) = usize::try_from(req) else { return false };
        self.fire(Kind::PoisonLogits, req, step, "")
    }

    /// Gateway: synthetic stall before pump iteration `iter` dispatches.
    pub fn queue_stall(&self, iter: usize) -> Option<u64> {
        self.fire_site(Kind::QueueStall, iter, 0, "").map(|s| s.ms)
    }
}

/// Error message marker for simulated mid-run kills; tests match on it.
pub const KILL_MARKER: &str = "simulated crash (fault injection)";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_grammar() {
        let p = FaultPlan::parse("nan@0.3, compile@block_par_step:2, exec@fwd, kill@1").unwrap();
        assert_eq!(p.sites.len(), 4);
        // nan fires exactly once at the right site
        assert!(!p.nan_loss(0, 2));
        assert!(!p.nan_loss(1, 3));
        assert!(p.nan_loss(0, 3));
        assert!(!p.nan_loss(0, 3), "nan site must be one-shot");
        // compile fails twice then recovers
        assert!(p.fail_compile("block_par_step.nano.g32").is_some());
        assert!(p.fail_compile("block_par_step.nano.g32").is_some());
        assert!(p.fail_compile("block_par_step.nano.g32").is_none());
        assert!(p.fail_compile("unrelated").is_none());
        // exec is persistent
        for _ in 0..5 {
            assert!(p.fail_exec("block_fp_fwd.nano").is_some());
        }
        // kill fires once
        assert!(!p.kill_after_block(0));
        assert!(p.kill_after_block(1));
        assert!(!p.kill_after_block(1));
    }

    #[test]
    fn parses_gateway_kinds() {
        let p = FaultPlan::parse("slow@3.4000, poison@7.2, stall@1.2500, kill@5").unwrap();
        // slow: one-shot, returns its delay
        assert_eq!(p.slow_step(2), None);
        assert_eq!(p.slow_step(3), Some(4000));
        assert_eq!(p.slow_step(3), None, "slow site must be one-shot");
        // poison: keyed on (request id, request-local step)
        assert!(!p.poison_logits(7, 1));
        assert!(!p.poison_logits(6, 2));
        assert!(p.poison_logits(7, 2));
        assert!(!p.poison_logits(7, 2), "poison site must be one-shot");
        // stall: keyed on pump iteration
        assert_eq!(p.queue_stall(1), Some(2500));
        assert_eq!(p.queue_stall(1), None);
        // kill@ doubles as a gateway decode-step kill
        assert!(!p.kill_at_step(4));
        assert!(p.kill_at_step(5));
        assert!(!p.kill_at_step(5));
    }

    #[test]
    fn rejects_garbage() {
        assert!(FaultPlan::parse("").is_err());
        assert!(FaultPlan::parse("nan@x.y").is_err());
        assert!(FaultPlan::parse("explode@0").is_err());
        assert!(FaultPlan::parse("compile@:3").is_err());
        assert!(FaultPlan::parse("nan@3").is_err());
        assert!(FaultPlan::parse("slow@3").is_err());
        assert!(FaultPlan::parse("poison@1.x").is_err());
        assert!(FaultPlan::parse("stall@.5").is_err());
    }
}
