//! Resilience layer for long calibration runs (robustness tentpole).
//!
//! Four cooperating pieces:
//!
//! * [`checkpoint`] — per-block, checksummed, atomically-written calibration
//!   checkpoints; a killed run resumes from the first incomplete block.
//! * [`sentinel`] — NaN/Inf/divergence detection in the soften loop with a
//!   rollback + learning-rate-backoff retry budget, then hardened-RTN
//!   fallback for the block.
//! * [`retry`] — bounded exponential-backoff retry for transient runtime
//!   faults (artifact compile/execute).
//! * [`fault`] — deterministic fault injection (`--inject-faults` /
//!   `TESSERAQ_FAULTS`) used by the integration harness to prove the
//!   recovery paths work.

pub mod checkpoint;
pub mod fault;
pub mod retry;
pub mod sentinel;

use std::path::PathBuf;
use std::rc::Rc;

pub use checkpoint::{BlockCheckpoint, CheckpointStore};
pub use fault::{FaultPlan, KILL_MARKER};
pub use retry::{with_retry, RetryPolicy};
pub use sentinel::{LossHealth, Sentinel, SentinelConfig};

/// Knobs for a fault-tolerant calibration run. `Default` enables the
/// sentinels and runtime retries but no checkpointing (opt-in via
/// `checkpoint_dir`) and no fault injection.
#[derive(Clone, Default)]
pub struct RobustConfig {
    /// Where to persist per-block checkpoints; `None` disables them.
    pub checkpoint_dir: Option<PathBuf>,
    /// Resume from a valid checkpoint prefix instead of starting fresh.
    pub resume: bool,
    pub sentinel: SentinelConfig,
    pub retry: RetryPolicy,
    /// Deterministic fault injection (tests / drills); `None` in production.
    pub faults: Option<Rc<FaultPlan>>,
}

impl std::fmt::Debug for RobustConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RobustConfig")
            .field("checkpoint_dir", &self.checkpoint_dir)
            .field("resume", &self.resume)
            .field("sentinel", &self.sentinel)
            .field("retry", &self.retry)
            .field("faults", &self.faults.is_some())
            .finish()
    }
}

impl RobustConfig {
    /// Everything off — bit-for-bit the pre-resilience behavior.
    pub fn disabled() -> Self {
        RobustConfig {
            checkpoint_dir: None,
            resume: false,
            sentinel: SentinelConfig::disabled(),
            retry: RetryPolicy::none(),
            faults: None,
        }
    }

    pub fn with_checkpoints(dir: impl Into<PathBuf>, resume: bool) -> Self {
        RobustConfig {
            checkpoint_dir: Some(dir.into()),
            resume,
            ..Default::default()
        }
    }
}
