//! Per-block calibration checkpoints: versioned, checksummed, atomically
//! written files that let a killed calibration run resume from the first
//! incomplete block.
//!
//! One file per completed block (`block_0007.tsqb`):
//!
//! ```text
//!   "TSQB" | version u32 | config fingerprint u64 | payload len u64
//!   payload (codes + effective QParams + BlockTrace, little-endian)
//!   crc32(payload) u32
//! ```
//!
//! Atomicity: payload is staged to `.block_NNNN.tsqb.tmp` in the same
//! directory, fsync'd, then renamed over the final name — a kill at any
//! point leaves either no file or a complete one. The fingerprint hashes
//! the calibration configuration (model, quant config, schedule, seed,
//! calibration tokens); a mismatch means the checkpoint belongs to a
//! different run and resume is refused for that and later blocks.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::coordinator::driver::{BlockStatus, BlockTrace};
use crate::quant::QParams;
use crate::tensor::Tensor;

pub const MAGIC: &[u8; 4] = b"TSQB";
/// v2: payload gained the `extras` section (method-specific side state,
/// e.g. LWC clip tensors). The version is part of the fingerprint input,
/// so v1 checkpoints are refused cleanly rather than misdecoded.
pub const VERSION: u32 = 2;

/// FNV-1a 64-bit — stable, dependency-free config fingerprint.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// CRC-32 (IEEE, reflected) — payload integrity check.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Everything needed to reconstruct one completed block: the final codes
/// + effective dequant params (what `CalibReport.quantized[l]` holds) and
/// the block's trace.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockCheckpoint {
    pub trace: BlockTrace,
    pub quantized: BTreeMap<String, (Vec<u16>, QParams)>,
    /// Method-specific side state (e.g. the LWC clip-logit tensors) the
    /// optimizer needs back on resume; empty for methods without any.
    pub extras: BTreeMap<String, Tensor>,
}

pub struct CheckpointStore {
    dir: PathBuf,
    fingerprint: u64,
}

impl CheckpointStore {
    pub fn new(dir: impl Into<PathBuf>, fingerprint: u64) -> Result<CheckpointStore> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        Ok(CheckpointStore { dir, fingerprint })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn block_path(&self, layer: usize) -> PathBuf {
        self.dir.join(format!("block_{layer:04}.tsqb"))
    }

    /// Atomically persist one completed block.
    pub fn save_block(&self, layer: usize, ckpt: &BlockCheckpoint) -> Result<()> {
        let payload = encode_payload(ckpt);
        let mut file = Vec::with_capacity(payload.len() + 28);
        file.extend_from_slice(MAGIC);
        file.extend_from_slice(&VERSION.to_le_bytes());
        file.extend_from_slice(&self.fingerprint.to_le_bytes());
        file.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        file.extend_from_slice(&payload);
        file.extend_from_slice(&crc32(&payload).to_le_bytes());

        let final_path = self.block_path(layer);
        let tmp_path = self.dir.join(format!(".block_{layer:04}.tsqb.tmp"));
        {
            let mut f = std::fs::File::create(&tmp_path)
                .with_context(|| format!("creating {}", tmp_path.display()))?;
            f.write_all(&file)
                .with_context(|| format!("writing {}", tmp_path.display()))?;
            f.sync_all()
                .with_context(|| format!("syncing {}", tmp_path.display()))?;
        }
        std::fs::rename(&tmp_path, &final_path).with_context(|| {
            format!("renaming {} -> {}", tmp_path.display(), final_path.display())
        })?;
        crate::obs::event(
            "checkpoint_write",
            &[("layer", layer.into()), ("bytes", file.len().into())],
        );
        Ok(())
    }

    /// Load and validate one block checkpoint. Errors distinguish missing
    /// files, corruption, version skew, and config-fingerprint mismatch.
    pub fn load_block(&self, layer: usize) -> Result<BlockCheckpoint> {
        let path = self.block_path(layer);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        let mut r = Reader::new(&bytes);
        let magic = r.take(4)?;
        if magic != MAGIC {
            bail!("{}: not a TSQB checkpoint", path.display());
        }
        let version = r.take_u32()?;
        if version != VERSION {
            bail!("{}: checkpoint version {version}, this build reads {VERSION}", path.display());
        }
        let fp = r.take_u64()?;
        if fp != self.fingerprint {
            bail!(
                "{}: config fingerprint mismatch (checkpoint {fp:#018x}, run {:#018x}); \
                 the calibration configuration changed since this checkpoint was written",
                path.display(),
                self.fingerprint
            );
        }
        let plen = r.take_u64()? as usize;
        let payload = r.take(plen)?.to_vec();
        let stored_crc = r.take_u32()?;
        let actual_crc = crc32(&payload);
        if stored_crc != actual_crc {
            bail!(
                "{}: checksum mismatch (stored {stored_crc:#010x}, computed {actual_crc:#010x}); \
                 checkpoint is corrupt",
                path.display()
            );
        }
        let ckpt = decode_payload(&payload)
            .with_context(|| format!("decoding checkpoint {}", path.display()))?;
        if ckpt.trace.layer != layer {
            bail!(
                "{}: contains block {} but was loaded as block {layer}",
                path.display(),
                ckpt.trace.layer
            );
        }
        Ok(ckpt)
    }

    /// The contiguous prefix of valid block checkpoints, stopping (with a
    /// warning) at the first missing, corrupt, or mismatched file. The
    /// returned length is the block index to resume from.
    pub fn load_prefix(&self, n_layers: usize) -> Vec<BlockCheckpoint> {
        let mut out = Vec::new();
        for l in 0..n_layers {
            if !self.block_path(l).exists() {
                break;
            }
            match self.load_block(l) {
                Ok(c) => {
                    crate::obs::event("checkpoint_load", &[("layer", l.into())]);
                    out.push(c);
                }
                Err(e) => {
                    crate::obs::warn(
                        "resume_stop",
                        &format!("[robust] stopping resume scan at block {l}: {e:#}"),
                        &[("layer", l.into())],
                    );
                    break;
                }
            }
        }
        out
    }

    /// Remove all checkpoint files (and stale temp files) in the store.
    pub fn clear(&self) -> Result<()> {
        for entry in std::fs::read_dir(&self.dir)
            .with_context(|| format!("listing {}", self.dir.display()))?
        {
            let path = entry?.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if name.ends_with(".tsqb") || name.ends_with(".tsqb.tmp") {
                std::fs::remove_file(&path)
                    .with_context(|| format!("removing {}", path.display()))?;
            }
        }
        Ok(())
    }
}

// -- payload encoding --------------------------------------------------------

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(b: &mut Vec<u8>, v: f32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_str(b: &mut Vec<u8>, s: &str) {
    put_u32(b, s.len() as u32);
    b.extend_from_slice(s.as_bytes());
}

fn encode_payload(ckpt: &BlockCheckpoint) -> Vec<u8> {
    let mut b = Vec::new();
    let t = &ckpt.trace;
    put_u32(&mut b, t.layer as u32);
    b.push(match t.status {
        BlockStatus::Optimized => 0u8,
        BlockStatus::RtnFallback => 1u8,
    });
    put_f32(&mut b, t.initial_loss);
    put_u32(&mut b, t.losses.len() as u32);
    for &l in &t.losses {
        put_f32(&mut b, l);
    }
    put_u32(&mut b, t.flips.len() as u32);
    for (name, &(flipped, total)) in &t.flips {
        put_str(&mut b, name);
        put_u64(&mut b, flipped as u64);
        put_u64(&mut b, total as u64);
    }
    put_u32(&mut b, ckpt.quantized.len() as u32);
    for (name, (codes, qp)) in &ckpt.quantized {
        put_str(&mut b, name);
        put_u64(&mut b, codes.len() as u64);
        for &c in codes {
            b.extend_from_slice(&c.to_le_bytes());
        }
        put_u32(&mut b, qp.group as u32);
        put_u32(&mut b, qp.s.shape[0] as u32);
        put_u32(&mut b, qp.s.shape[1] as u32);
        for &v in &qp.s.data {
            put_f32(&mut b, v);
        }
        for &v in &qp.z.data {
            put_f32(&mut b, v);
        }
    }
    put_u32(&mut b, ckpt.extras.len() as u32);
    for (name, t) in &ckpt.extras {
        put_str(&mut b, name);
        put_u32(&mut b, t.shape.len() as u32);
        for &d in &t.shape {
            put_u32(&mut b, d as u32);
        }
        for &v in &t.data {
            put_f32(&mut b, v);
        }
    }
    b
}

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        Reader { bytes, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            bail!("truncated at offset {} (wanted {n} bytes of {})", self.pos, self.bytes.len());
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn take_u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn take_u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    fn take_f32(&mut self) -> Result<f32> {
        let s = self.take(4)?;
        Ok(f32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn take_u16(&mut self) -> Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn take_str(&mut self) -> Result<String> {
        let n = self.take_u32()? as usize;
        if n > 1 << 16 {
            bail!("string too long ({n})");
        }
        Ok(String::from_utf8(self.take(n)?.to_vec())?)
    }
}

fn decode_payload(payload: &[u8]) -> Result<BlockCheckpoint> {
    let mut r = Reader::new(payload);
    let layer = r.take_u32()? as usize;
    let status = match r.take(1)?[0] {
        0 => BlockStatus::Optimized,
        1 => BlockStatus::RtnFallback,
        t => bail!("unknown block status tag {t}"),
    };
    let initial_loss = r.take_f32()?;
    let n_losses = r.take_u32()? as usize;
    let mut losses = Vec::with_capacity(n_losses);
    for _ in 0..n_losses {
        losses.push(r.take_f32()?);
    }
    let n_flips = r.take_u32()? as usize;
    let mut flips = BTreeMap::new();
    for _ in 0..n_flips {
        let name = r.take_str()?;
        let flipped = r.take_u64()? as usize;
        let total = r.take_u64()? as usize;
        flips.insert(name, (flipped, total));
    }
    let n_lin = r.take_u32()? as usize;
    let mut quantized = BTreeMap::new();
    for _ in 0..n_lin {
        let name = r.take_str()?;
        let n_codes = r.take_u64()? as usize;
        let mut codes = Vec::with_capacity(n_codes);
        for _ in 0..n_codes {
            codes.push(r.take_u16()?);
        }
        let group = r.take_u32()? as usize;
        let o = r.take_u32()? as usize;
        let ng = r.take_u32()? as usize;
        let mut s = Vec::with_capacity(o * ng);
        for _ in 0..o * ng {
            s.push(r.take_f32()?);
        }
        let mut z = Vec::with_capacity(o * ng);
        for _ in 0..o * ng {
            z.push(r.take_f32()?);
        }
        let qp = QParams {
            s: Tensor::new(vec![o, ng], s),
            z: Tensor::new(vec![o, ng], z),
            group,
        };
        quantized.insert(name, (codes, qp));
    }
    let n_extras = r.take_u32()? as usize;
    let mut extras = BTreeMap::new();
    for _ in 0..n_extras {
        let name = r.take_str()?;
        let rank = r.take_u32()? as usize;
        if rank > 8 {
            bail!("extras tensor rank too large ({rank})");
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(r.take_u32()? as usize);
        }
        let n: usize = shape.iter().product();
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(r.take_f32()?);
        }
        extras.insert(name, Tensor::new(shape, data));
    }
    Ok(BlockCheckpoint {
        trace: BlockTrace { layer, losses, flips, initial_loss, status },
        quantized,
        extras,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("tsqb_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    fn mk_ckpt(layer: usize) -> BlockCheckpoint {
        let mut flips = BTreeMap::new();
        flips.insert("q_proj".to_string(), (3usize, 64usize));
        flips.insert("down_proj".to_string(), (0usize, 128usize));
        let mut quantized = BTreeMap::new();
        for (i, name) in ["q_proj", "down_proj"].iter().enumerate() {
            let codes: Vec<u16> = (0..24).map(|c| ((c + i) % 4) as u16).collect();
            let qp = QParams {
                s: Tensor::from_fn(&[4, 2], |j| 0.01 + j as f32 * 0.003),
                z: Tensor::from_fn(&[4, 2], |j| (j % 3) as f32),
                group: 3,
            };
            quantized.insert(name.to_string(), (codes, qp));
        }
        let mut extras = BTreeMap::new();
        extras.insert("gm:q_proj".to_string(), Tensor::from_fn(&[4, 2], |j| 4.0 - j as f32 * 0.1));
        BlockCheckpoint {
            trace: BlockTrace {
                layer,
                losses: vec![0.5, 0.25, 0.125],
                flips,
                initial_loss: 0.75,
                status: BlockStatus::Optimized,
            },
            quantized,
            extras,
        }
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let store = CheckpointStore::new(test_dir("roundtrip"), 0xDEAD_BEEF).unwrap();
        let ckpt = mk_ckpt(0);
        store.save_block(0, &ckpt).unwrap();
        let back = store.load_block(0).unwrap();
        assert_eq!(ckpt, back);
        store.clear().unwrap();
    }

    #[test]
    fn corruption_is_detected() {
        let store = CheckpointStore::new(test_dir("corrupt"), 1).unwrap();
        store.save_block(0, &mk_ckpt(0)).unwrap();
        let path = store.block_path(0);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", store.load_block(0).unwrap_err());
        assert!(err.contains("checksum") || err.contains("decoding"), "{err}");
    }

    #[test]
    fn fingerprint_mismatch_is_refused() {
        let dir = test_dir("fingerprint");
        let store = CheckpointStore::new(&dir, 42).unwrap();
        store.save_block(0, &mk_ckpt(0)).unwrap();
        let other = CheckpointStore::new(&dir, 43).unwrap();
        let err = format!("{:#}", other.load_block(0).unwrap_err());
        assert!(err.contains("fingerprint mismatch"), "{err}");
        // and the resume scan treats it as "nothing to resume"
        assert!(other.load_prefix(4).is_empty());
    }

    #[test]
    fn prefix_stops_at_first_gap() {
        let store = CheckpointStore::new(test_dir("prefix"), 7).unwrap();
        store.save_block(0, &mk_ckpt(0)).unwrap();
        store.save_block(2, &mk_ckpt(2)).unwrap();
        let prefix = store.load_prefix(4);
        assert_eq!(prefix.len(), 1);
        assert_eq!(prefix[0].trace.layer, 0);
    }

    #[test]
    fn hash_functions_match_known_vectors() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }
}
