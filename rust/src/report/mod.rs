//! Result reporting: Markdown tables printed to stdout and written to
//! results/, matching the row/column shapes of the paper's tables.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::Result;

pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut s = String::new();
        let _ = writeln!(s, "### {}\n", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            let parts: Vec<String> = cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}", w = w))
                .collect();
            format!("| {} |", parts.join(" | "))
        };
        let _ = writeln!(s, "{}", line(&self.headers, &widths));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        let _ = writeln!(s, "{}", line(&sep, &widths));
        for row in &self.rows {
            let _ = writeln!(s, "{}", line(row, &widths));
        }
        s
    }

    /// Print to stdout and persist under results/<name>.md.
    pub fn emit(&self, name: &str) -> Result<PathBuf> {
        let md = self.to_markdown();
        println!("\n{md}");
        let dir = results_dir();
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{name}.md"));
        std::fs::write(&path, &md)?;
        Ok(path)
    }
}

pub fn results_dir() -> PathBuf {
    if let Ok(d) = std::env::var("TESSERAQ_RESULTS") {
        return d.into();
    }
    // next to artifacts/
    let art = crate::default_artifact_dir();
    art.parent().map(|p| p.join("results")).unwrap_or_else(|| "results".into())
}

pub fn fmt_ppl(p: f64) -> String {
    if !p.is_finite() || p > 1e4 {
        format!("{:.1e}", p)
    } else {
        format!("{p:.2}")
    }
}

pub fn fmt_acc(a: f64) -> String {
    format!("{:.2}", a * 100.0)
}

pub fn fmt_bytes(b: usize) -> String {
    if b > 1 << 20 {
        format!("{:.1}MB", b as f64 / (1 << 20) as f64)
    } else {
        format!("{:.1}KB", b as f64 / 1024.0)
    }
}

/// Persist a JSON artifact under results/<name>.json (next to the
/// markdown tables). `text` must already be serialized JSON.
pub fn write_json(name: &str, text: &str) -> Result<PathBuf> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let safe: String = name
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '-' || c == '_' || c == '.' {
                c
            } else {
                '_'
            }
        })
        .collect();
    let path = dir.join(format!("{safe}.json"));
    std::fs::write(&path, text)?;
    Ok(path)
}

/// Append a section to EXPERIMENTS.md-style logs under results/.
pub fn append_log(file: &str, text: &str) -> Result<()> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(file);
    let mut cur = std::fs::read_to_string(&path).unwrap_or_default();
    cur.push_str(text);
    cur.push('\n');
    std::fs::write(&path, cur)?;
    Ok(())
}

pub fn exists(p: &Path) -> bool {
    p.exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("Demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() == 3);
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_ppl(6.816), "6.82");
        assert!(fmt_ppl(2.9e6).contains('e'));
        assert_eq!(fmt_acc(0.5927), "59.27");
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
