//! GPTQ (Frantar et al. 2022): column-wise optimal quantization with
//! Hessian-based error compensation, implemented from the paper's
//! equations in f64 (damped Cholesky inverse of H = X^T X).
//!
//! Per column j (in order): quantize w_j, compute the residual
//! delta = (w_j - q_j) / [H^-1]_{jj}, and update remaining columns
//! w_k -= delta * [H^-1]_{jk}. Group scales are (re)computed when a group
//! boundary is entered, matching the per-group GPTQ variant.

use crate::quant::{minmax_scale, round_te, ClipFactors, QParams, QuantConfig};
use crate::tensor::linalg::{cholesky_inplace, gram_f64, spd_inverse_from_cholesky};
use crate::tensor::Tensor;

pub struct GptqOutput {
    /// Fake-quantized weight [out, in].
    pub wq: Tensor,
    /// Group quant params actually used.
    pub qp: QParams,
    /// Integer codes [out*in] on the final grid — `dequant_codes` over
    /// these with `qp` reproduces the serving-path weights.
    pub codes: Vec<u16>,
}

/// Quantize one linear with GPTQ given its input activations x [rows, in].
pub fn gptq_linear(
    w: &Tensor,
    x: &Tensor,
    qcfg: &QuantConfig,
    damp: f64,
) -> GptqOutput {
    let (o, i) = w.dims2();
    let g = qcfg.scheme.group_size(i);
    let ng = i / g;
    let qmax = qcfg.qmax_w();

    // H = X^T X + damp * mean(diag) * I
    let mut h = gram_f64(x);
    let mean_diag: f64 =
        (0..i).map(|t| h[t * i + t]).sum::<f64>() / i as f64;
    let lambda = (damp * mean_diag).max(1e-8);
    for t in 0..i {
        h[t * i + t] += lambda;
    }
    cholesky_inplace(&mut h, i).expect("damped Hessian must be SPD");
    let hinv = spd_inverse_from_cholesky(&h, i);

    // Working copy in f64 for stable error propagation.
    let mut wf: Vec<f64> = w.data.iter().map(|&v| v as f64).collect();
    let mut s = Tensor::zeros(&[o, ng]);
    let mut z = Tensor::zeros(&[o, ng]);
    let mut wq = vec![0.0f32; o * i];
    let mut codes = vec![0u16; o * i];

    for j in 0..i {
        let gi = j / g;
        if j % g == 0 {
            // (re)compute group scales from the *current* residual weights
            let cur = Tensor::new(
                vec![o, g],
                (0..o)
                    .flat_map(|r| {
                        wf[r * i + gi * g..r * i + (gi + 1) * g]
                            .iter()
                            .map(|&v| v as f32)
                            .collect::<Vec<_>>()
                    })
                    .collect(),
            );
            let qp = minmax_scale(
                &cur,
                g,
                &ClipFactors::Uniform(1.0),
                &ClipFactors::Uniform(1.0),
                qmax,
            );
            for r in 0..o {
                s.data[r * ng + gi] = qp.s.data[r];
                z.data[r * ng + gi] = qp.z.data[r];
            }
        }
        let hjj = hinv[j * i + j];
        for r in 0..o {
            let sv = s.data[r * ng + gi] as f64;
            let zv = z.data[r * ng + gi] as f64;
            let wv = wf[r * i + j];
            let q = (round_te((wv / sv) as f32) as f64 + zv).clamp(0.0, qmax as f64);
            let deq = sv * (q - zv);
            wq[r * i + j] = deq as f32;
            codes[r * i + j] = q as u16;
            let err = (wv - deq) / hjj;
            // propagate to the remaining columns
            for k in (j + 1)..i {
                wf[r * i + k] -= err * hinv[j * i + k];
            }
        }
    }

    GptqOutput {
        wq: Tensor::new(vec![o, i], wq),
        qp: QParams { s, z, group: g },
        codes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{rtn_qdq, GroupScheme};
    use crate::tensor::{linalg, Pcg32};

    fn layer_err(x: &Tensor, w: &Tensor, wq: &Tensor) -> f64 {
        let y = linalg::matmul_bt(x, w);
        let yq = linalg::matmul_bt(x, wq);
        yq.mse(&y)
    }

    #[test]
    fn gptq_beats_rtn_on_layer_objective() {
        let mut rng = Pcg32::seeded(0);
        let (o, i) = (24, 48);
        let w = Tensor::randn(&[o, i], 1.0, &mut rng);
        // correlated inputs: where GPTQ's error compensation pays off
        let base = Tensor::randn(&[256, 8], 1.0, &mut rng);
        let mixer = Tensor::randn(&[i, 8], 1.0, &mut rng);
        let mut x = linalg::matmul_bt(&base, &mixer); // [256, i], rank 8
        for v in x.data.iter_mut() {
            *v += 0.05 * rng.normal() as f32; // small noise
        }
        let qcfg = QuantConfig::weight_only(2, GroupScheme::Group(16));
        let qmax = qcfg.qmax_w();
        let qp = minmax_scale(&w, 16, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), qmax);
        let w_rtn = rtn_qdq(&w, &qp, qmax);
        let out = gptq_linear(&w, &x, &qcfg, 0.01);
        let e_rtn = layer_err(&x, &w, &w_rtn);
        let e_gptq = layer_err(&x, &w, &out.wq);
        assert!(
            e_gptq < e_rtn * 0.9,
            "GPTQ {e_gptq} should beat RTN {e_rtn} by >10%"
        );
    }

    #[test]
    fn gptq_output_is_on_grid() {
        let mut rng = Pcg32::seeded(1);
        let (o, i) = (8, 32);
        let w = Tensor::randn(&[o, i], 1.0, &mut rng);
        let x = Tensor::randn(&[64, i], 1.0, &mut rng);
        let qcfg = QuantConfig::weight_only(3, GroupScheme::Group(16));
        let out = gptq_linear(&w, &x, &qcfg, 0.01);
        let ng = 2;
        for r in 0..o {
            for c in 0..i {
                let s = out.qp.s.data[r * ng + c / 16];
                let z = out.qp.z.data[r * ng + c / 16];
                let code = out.wq.data[r * i + c] / s + z;
                assert!(
                    (code - code.round()).abs() < 1e-3,
                    "({r},{c}) code {code} off-grid"
                );
                assert!(code.round() >= -0.5 && code.round() <= 7.5);
            }
        }
    }

    #[test]
    fn gptq_codes_match_dequant_path() {
        let mut rng = Pcg32::seeded(3);
        let (o, i) = (8, 32);
        let w = Tensor::randn(&[o, i], 1.0, &mut rng);
        let x = Tensor::randn(&[64, i], 1.0, &mut rng);
        let qcfg = QuantConfig::weight_only(3, GroupScheme::Group(16));
        let out = gptq_linear(&w, &x, &qcfg, 0.01);
        assert_eq!(out.codes.len(), o * i);
        let deq = crate::quant::dequant_codes(&out.codes, o, i, &out.qp);
        for (idx, (a, b)) in deq.data.iter().zip(&out.wq.data).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * b.abs().max(1.0),
                "elem {idx}: dequant {a} vs wq {b}"
            );
        }
    }

    #[test]
    fn gptq_handles_rank_deficient_inputs() {
        // all-identical rows: H is rank 1; damping must keep it SPD
        let mut rng = Pcg32::seeded(2);
        let (o, i) = (4, 16);
        let w = Tensor::randn(&[o, i], 1.0, &mut rng);
        let row: Vec<f32> = (0..i).map(|_| rng.normal() as f32).collect();
        let mut xd = Vec::new();
        for _ in 0..32 {
            xd.extend_from_slice(&row);
        }
        let x = Tensor::new(vec![32, i], xd);
        let qcfg = QuantConfig::weight_only(4, GroupScheme::PerChannel);
        let out = gptq_linear(&w, &x, &qcfg, 0.01);
        assert!(out.wq.data.iter().all(|v| v.is_finite()));
    }
}
