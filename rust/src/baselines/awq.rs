//! AWQ (Lin et al. 2023): activation-aware weight scaling + asymmetric
//! clipping, searched per linear against the layer-output MSE (Eq. 2).
//!
//! Scale search: s_j = mean|x_j|^alpha over a grid alpha in [0, 1); the
//! scaled weight W diag(s) is RTN-quantized and evaluated on
//! (x / s) @ qdq(W diag(s))^T vs x @ W^T. The chosen scales are folded
//! into the same equivalence carriers as SmoothQuant (norm1/norm2,
//! v_proj/up_proj rows), so the FP model is unchanged.
//!
//! Clip search: per-group grid over shrink factors of (gamma, beta)
//! minimizing the activation-weighted weight reconstruction error
//! (asymmetric clipping, following Gong et al. 2024's implementation the
//! paper cites for its AWQ numbers).

use std::collections::BTreeMap;

use crate::model::hostfwd::{block_fwd, BlockFwdOpts, tap_for_linear};
use crate::model::transform::{scale_cols, scale_rows};
use crate::model::Params;
use crate::quant::{minmax_scale, rtn_qdq, ClipFactors, QParams, QuantConfig};
use crate::tensor::{linalg, Tensor};

pub struct AwqResult {
    /// chosen alpha per (layer, linear)
    pub alphas: Vec<BTreeMap<String, f32>>,
    /// per-linear clip factors, to be used at quantization time
    pub clips: Vec<BTreeMap<String, (Tensor, Tensor)>>,
}

/// Sub-sample rows of a tap matrix to bound the search cost.
fn subsample(x: &Tensor, max_rows: usize, stride_seed: usize) -> Tensor {
    let (rows, ch) = x.dims2();
    if rows <= max_rows {
        return x.clone();
    }
    let stride = rows / max_rows;
    let mut data = Vec::with_capacity(max_rows * ch);
    let mut r = stride_seed % stride;
    while data.len() < max_rows * ch && r < rows {
        data.extend_from_slice(&x.data[r * ch..(r + 1) * ch]);
        r += stride;
    }
    let n = data.len() / ch;
    Tensor::new(vec![n, ch], data)
}

/// Per-channel mean |x|.
fn act_mean_abs(x: &Tensor) -> Vec<f32> {
    let (rows, ch) = x.dims2();
    let mut m = vec![0.0f32; ch];
    for r in 0..rows {
        for c in 0..ch {
            m[c] += x.data[r * ch + c].abs();
        }
    }
    for v in &mut m {
        *v /= rows as f32;
    }
    m
}

/// Search the AWQ scale exponent for one linear; returns (alpha, scales).
pub fn search_scale(
    w: &Tensor,
    x: &Tensor,
    qcfg: &QuantConfig,
    grid: usize,
) -> (f32, Vec<f32>) {
    let (_, i) = w.dims2();
    let g = qcfg.scheme.group_size(i);
    let qmax = qcfg.qmax_w();
    let act_mean = act_mean_abs(x);
    let y_ref = linalg::matmul_bt(x, w);
    let mut best = (f32::INFINITY, 0.0f32, vec![1.0f32; i]);
    for gi in 0..grid {
        let alpha = gi as f32 / grid as f32;
        let s: Vec<f32> =
            act_mean.iter().map(|&a| a.max(1e-5).powf(alpha).clamp(1e-4, 1e4)).collect();
        let mut ws = w.clone();
        scale_cols(&mut ws, &s);
        let qp = minmax_scale(&ws, g, &ClipFactors::Uniform(1.0), &ClipFactors::Uniform(1.0), qmax);
        let wq = rtn_qdq(&ws, &qp, qmax);
        // y = (x / s) @ wq^T
        let mut xs = x.clone();
        let inv: Vec<f32> = s.iter().map(|v| 1.0 / v).collect();
        scale_cols(&mut xs, &inv);
        let y = linalg::matmul_bt(&xs, &wq);
        let err = y.mse(&y_ref) as f32;
        if err < best.0 {
            best = (err, alpha, s);
        }
    }
    (best.1, best.2)
}

/// Asymmetric per-group clip search on the (already scaled) weight,
/// minimizing sum_j actnorm_j * (w_ij - qdq(w)_ij)^2 per group.
pub fn search_clip(
    w: &Tensor,
    act_mean: &[f32],
    qcfg: &QuantConfig,
    grid: usize,
) -> (Tensor, Tensor) {
    let (o, i) = w.dims2();
    let g = qcfg.scheme.group_size(i);
    let ng = i / g;
    let qmax = qcfg.qmax_w();
    let mut gamma = Tensor::full(&[o, ng], 1.0);
    let mut beta = Tensor::full(&[o, ng], 1.0);
    for r in 0..o {
        for gi in 0..ng {
            let seg = &w.data[r * i + gi * g..r * i + (gi + 1) * g];
            let aw = &act_mean[gi * g..(gi + 1) * g];
            let mx = seg.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let mn = seg.iter().fold(f32::INFINITY, |m, &v| m.min(v));
            let mut best = (f32::INFINITY, 1.0f32, 1.0f32);
            for a in 0..grid {
                let ga = 1.0 - a as f32 * 0.5 / grid as f32; // [0.5, 1.0]
                for b in 0..grid {
                    let be = 1.0 - b as f32 * 0.5 / grid as f32;
                    let s = ((ga * mx - be * mn) / qmax).max(1e-9);
                    let z = crate::quant::round_te(-be * mn / s);
                    let mut err = 0.0f32;
                    for (t, &wv) in seg.iter().enumerate() {
                        let q = (crate::quant::round_te(wv / s) + z).clamp(0.0, qmax);
                        let d = wv - s * (q - z);
                        err += aw[t] * d * d;
                    }
                    if err < best.0 {
                        best = (err, ga, be);
                    }
                }
            }
            gamma.data[r * ng + gi] = best.1;
            beta.data[r * ng + gi] = best.2;
        }
    }
    (gamma, beta)
}

/// Run AWQ over the whole model, folding scales into carriers and
/// returning the clip factors to use when quantizing.
pub fn awq_transform(
    params: &mut Params,
    calib_x: &Tensor,
    qcfg: &QuantConfig,
    scale_grid: usize,
    clip_grid: usize,
) -> AwqResult {
    let cfg = params.cfg.clone();
    let mut x = calib_x.clone();
    let mut alphas = Vec::new();
    let mut clips = Vec::new();
    for l in 0..cfg.n_layers {
        let opts = BlockFwdOpts { act_qmax: None, collect: true };
        let (y, taps) = block_fwd(&x, &params.block(l), &cfg, &opts);

        let mut layer_alphas = BTreeMap::new();
        // Group scale searches by carrier site so the fold stays exact.
        // qkv site: one shared scale (searched on q_proj, the largest
        // consumer), folded into norm1.
        let site_defs: [(&str, &[&str]); 4] = [
            ("qkv_in", &["q_proj", "k_proj", "v_proj"]),
            ("o_in", &["o_proj"]),
            ("mlp_in", &["gate_proj", "up_proj"]),
            ("down_in", &["down_proj"]),
        ];
        for (tap, members) in site_defs {
            let xs = subsample(&taps[tap], 512, l);
            let (alpha, s) = search_scale(&params.get(members[0]).index0(l), &xs, qcfg, scale_grid);
            for name in members {
                layer_alphas.insert(name.to_string(), alpha);
                let mut w = params.get(name).index0(l);
                scale_cols(&mut w, &s);
                params.set_block_linear(l, name, &w);
            }
            let inv: Vec<f32> = s.iter().map(|v| 1.0 / v).collect();
            match tap {
                "qkv_in" => {
                    let mut n1 = params.get("norm1").index0(l);
                    for (nv, iv) in n1.data.iter_mut().zip(&inv) {
                        *nv *= iv;
                    }
                    params.get_mut("norm1").set_index0(l, &n1);
                }
                "mlp_in" => {
                    let mut n2 = params.get("norm2").index0(l);
                    for (nv, iv) in n2.data.iter_mut().zip(&inv) {
                        *nv *= iv;
                    }
                    params.get_mut("norm2").set_index0(l, &n2);
                }
                "o_in" => {
                    // fold into v rows (average across GQA repeats)
                    let rep = cfg.n_heads / cfg.n_kv_heads;
                    let hd = cfg.head_dim();
                    let mut vinv = vec![0.0f32; cfg.d_kv()];
                    for kvh in 0..cfg.n_kv_heads {
                        for t in 0..hd {
                            let mut acc = 0.0;
                            for r in 0..rep {
                                acc += inv[(kvh * rep + r) * hd + t];
                            }
                            vinv[kvh * hd + t] = acc / rep as f32;
                        }
                    }
                    let mut wv = params.get("v_proj").index0(l);
                    scale_rows(&mut wv, &vinv);
                    params.set_block_linear(l, "v_proj", &wv);
                }
                "down_in" => {
                    let mut wu = params.get("up_proj").index0(l);
                    scale_rows(&mut wu, &inv);
                    params.set_block_linear(l, "up_proj", &wu);
                }
                _ => unreachable!(),
            }
        }

        // clip search per linear on the transformed weights
        let mut layer_clips = BTreeMap::new();
        for (name, _) in cfg.linear_shapes() {
            let xs = subsample(&taps[tap_for_linear(name)], 256, l);
            let am = act_mean_abs(&xs);
            let w = params.get(name).index0(l);
            let (gm, bt) = search_clip(&w, &am, qcfg, clip_grid);
            layer_clips.insert(name.to_string(), (gm, bt));
        }

        alphas.push(layer_alphas);
        clips.push(layer_clips);
        x = y;
    }
    AwqResult { alphas, clips }
}

/// RTN-quantize all linears using AWQ clip factors (the "AWQ" baseline
/// rows in the tables). Returns per-linear QParams for later reuse.
pub fn quantize_with_clips(
    params: &mut Params,
    clips: &[BTreeMap<String, (Tensor, Tensor)>],
    qcfg: &QuantConfig,
) -> Vec<BTreeMap<String, QParams>> {
    let cfg = params.cfg.clone();
    let qmax = qcfg.qmax_w();
    let mut out = Vec::new();
    for l in 0..cfg.n_layers {
        let mut layer = BTreeMap::new();
        for (name, (o, i)) in cfg.linear_shapes() {
            let g = qcfg.scheme.group_size(i);
            let w = params.get(name).index0(l);
            let (gm, bt) = &clips[l][name];
            let qp = minmax_scale(
                &w,
                g,
                &ClipFactors::PerGroup(gm.clone()),
                &ClipFactors::PerGroup(bt.clone()),
                qmax,
            );
            let wq = rtn_qdq(&w, &qp, qmax);
            params.set_block_linear(l, name, &wq);
            layer.insert(name.to_string(), qp);
            let _ = o;
        }
        out.push(layer);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::quant::GroupScheme;
    use crate::tensor::Pcg32;

    #[test]
    fn awq_transform_preserves_fp_function() {
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg32::seeded(0);
        let mut p = Params::init(&cfg, &mut rng);
        let x = Tensor::randn(&[2, 16, cfg.d_model], 1.0, &mut rng);
        let run = |p: &Params| {
            let mut h = x.clone();
            for l in 0..cfg.n_layers {
                h = block_fwd(&h, &p.block(l), &cfg, &BlockFwdOpts::default()).0;
            }
            h
        };
        let y0 = run(&p);
        let qcfg = QuantConfig::weight_only(3, GroupScheme::Group(32));
        awq_transform(&mut p, &x, &qcfg, 8, 4);
        let y1 = run(&p);
        assert!(y0.mse(&y1) < 1e-6, "AWQ fold broke equivalence: {}", y0.mse(&y1));
    }

    #[test]
    fn awq_beats_plain_rtn_on_outlier_inputs() {
        // Craft a layer whose input has a huge outlier channel: AWQ's
        // activation-aware scaling must reduce quantized output MSE.
        let mut rng = Pcg32::seeded(1);
        let (o, i) = (32, 64);
        let w = Tensor::randn(&[o, i], 1.0, &mut rng);
        let mut x = Tensor::randn(&[128, i], 1.0, &mut rng);
        for r in 0..128 {
            x.data[r * i + 5] *= 40.0; // salient channel
        }
        let qcfg = QuantConfig::weight_only(2, GroupScheme::Group(32));
        let qmax = qcfg.qmax_w();
        let y_ref = linalg::matmul_bt(&x, &w);
        // plain RTN
        let qp = minmax_scale(&w, 32, &ClipFactors::Uniform(1.0), &ClipFactors::Uniform(1.0), qmax);
        let y_rtn = linalg::matmul_bt(&x, &rtn_qdq(&w, &qp, qmax));
        // AWQ scale
        let (_, s) = search_scale(&w, &x, &qcfg, 16);
        let mut ws = w.clone();
        scale_cols(&mut ws, &s);
        let qps = minmax_scale(&ws, 32, &ClipFactors::Uniform(1.0), &ClipFactors::Uniform(1.0), qmax);
        let wq = rtn_qdq(&ws, &qps, qmax);
        let mut xs = x.clone();
        let inv: Vec<f32> = s.iter().map(|v| 1.0 / v).collect();
        scale_cols(&mut xs, &inv);
        let y_awq = linalg::matmul_bt(&xs, &wq);
        let e_rtn = y_rtn.mse(&y_ref);
        let e_awq = y_awq.mse(&y_ref);
        assert!(e_awq < e_rtn, "AWQ {e_awq} !< RTN {e_rtn}");
    }

    #[test]
    fn clip_search_improves_weighted_error() {
        let mut rng = Pcg32::seeded(2);
        let (o, i) = (16, 32);
        let mut w = Tensor::randn(&[o, i], 1.0, &mut rng);
        // inject rare huge weights that blow up the RTN step size
        w.data[3] = 12.0;
        w.data[40] = -9.0;
        let am = vec![1.0f32; i];
        let qcfg = QuantConfig::weight_only(2, GroupScheme::PerChannel);
        let qmax = qcfg.qmax_w();
        let err_of = |gm: &ClipFactors, bt: &ClipFactors| {
            let qp = minmax_scale(&w, 32, gm, bt, qmax);
            rtn_qdq(&w, &qp, qmax).mse(&w)
        };
        let base = err_of(&ClipFactors::Uniform(1.0), &ClipFactors::Uniform(1.0));
        let (gm, bt) = search_clip(&w, &am, &qcfg, 8);
        let clipped = err_of(&ClipFactors::PerGroup(gm), &ClipFactors::PerGroup(bt));
        assert!(clipped <= base, "clip {clipped} !<= base {base}");
    }
}
