//! PTQ baselines the paper compares against (Tables 1-4, Fig. 2):
//! RTN (in quant/), AWQ scale+clip search, GPTQ (Hessian/Cholesky),
//! OmniQuant-style LWC (driver in coordinator/lwc.rs, step artifact at L2)
//! and SmoothQuant / QuaRot (in quant/).

pub mod awq;
pub mod gptq;
