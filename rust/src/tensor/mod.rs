//! Minimal host tensor: contiguous f32 storage + shape.
//!
//! This is deliberately tiny — the heavy math runs inside the AOT-compiled
//! XLA artifacts; the host side only needs initialization, reshaping,
//! scoring and the quantizer arithmetic (which must mirror
//! python/compile/quantize.py bit-for-bit).

pub mod linalg;
pub mod rng;

pub use rng::Pcg32;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data len {}",
            shape,
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: (0..n).map(&mut f).collect() }
    }

    /// Gaussian init, N(0, std^2).
    pub fn randn(shape: &[usize], std: f32, rng: &mut Pcg32) -> Self {
        Tensor::from_fn(shape, |_| rng.normal() as f32 * std)
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Rows/cols of a 2-D tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.shape.len(), 2, "expected 2-D, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Slice out index `i` of the leading dimension (e.g. one layer of a
    /// stacked [L, ...] parameter tensor).
    pub fn index0(&self, i: usize) -> Tensor {
        assert!(!self.shape.is_empty() && i < self.shape[0]);
        let sub: usize = self.shape[1..].iter().product();
        Tensor {
            shape: self.shape[1..].to_vec(),
            data: self.data[i * sub..(i + 1) * sub].to_vec(),
        }
    }

    /// Write `src` into index `i` of the leading dimension.
    pub fn set_index0(&mut self, i: usize, src: &Tensor) {
        let sub: usize = self.shape[1..].iter().product();
        assert_eq!(src.data.len(), sub);
        self.data[i * sub..(i + 1) * sub].copy_from_slice(&src.data);
    }

    /// Stack tensors of identical shape along a new leading dim.
    pub fn stack(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let sh = &parts[0].shape;
        let mut shape = vec![parts.len()];
        shape.extend_from_slice(sh);
        let mut data = Vec::with_capacity(parts.len() * parts[0].numel());
        for p in parts {
            assert_eq!(&p.shape, sh);
            data.extend_from_slice(&p.data);
        }
        Tensor { shape, data }
    }

    pub fn transpose2d(&self) -> Tensor {
        let (r, c) = self.dims2();
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::new(vec![c, r], out)
    }

    pub fn map(&self, f: impl Fn(f32) -> f32 + Sync) -> Tensor {
        // Large tensors (block-stream activations, stacked weights) go
        // through the util thread pool; order is preserved either way.
        if self.data.len() >= 64 * 1024 {
            return Tensor {
                shape: self.shape.clone(),
                data: crate::util::parallel_map(&self.data, f),
            };
        }
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        let s: f64 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum();
        s / self.data.len() as f64
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn mean(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum::<f64>() / self.data.len().max(1) as f64
    }

    /// y = x @ self^T where self is [out, in] and x is [m, in].
    pub fn matmul_bt(&self, x: &Tensor) -> Tensor {
        linalg::matmul_bt(x, self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index0_roundtrip() {
        let t = Tensor::from_fn(&[3, 2, 2], |i| i as f32);
        let l1 = t.index0(1);
        assert_eq!(l1.shape, vec![2, 2]);
        assert_eq!(l1.data, vec![4.0, 5.0, 6.0, 7.0]);
        let mut t2 = t.clone();
        t2.set_index0(1, &Tensor::zeros(&[2, 2]));
        assert_eq!(t2.index0(1).data, vec![0.0; 4]);
        assert_eq!(t2.index0(0).data, t.index0(0).data);
    }

    #[test]
    fn stack_matches_index0() {
        let a = Tensor::from_fn(&[2, 3], |i| i as f32);
        let b = Tensor::from_fn(&[2, 3], |i| (i * 10) as f32);
        let s = Tensor::stack(&[a.clone(), b.clone()]);
        assert_eq!(s.shape, vec![2, 2, 3]);
        assert_eq!(s.index0(0), a);
        assert_eq!(s.index0(1), b);
    }

    #[test]
    fn transpose_involution() {
        let t = Tensor::from_fn(&[3, 5], |i| i as f32);
        assert_eq!(t.transpose2d().transpose2d(), t);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![0.0; 3]);
    }
}
