//! Host linear algebra for the coordinator: threaded matmul (AWQ/GPTQ
//! searches), Cholesky (GPTQ Hessian), and fast Walsh-Hadamard transform
//! (QuaRot-style rotations). Heavy model math stays in the XLA artifacts;
//! these run on calibration-sized problems only.

use super::Tensor;
use crate::util::{parallel_chunks, parallel_rows};

/// Dot product with four independent accumulators. Every matmul in the
/// serving hot path funnels through this one function so dense prefill,
/// incremental decode and the packed kernel accumulate in the identical
/// order — batched generation stays bit-identical to solo generation.
#[inline]
pub fn dot_unrolled(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let head = n - n % 4;
    let mut acc = [0.0f32; 4];
    let mut t = 0;
    while t < head {
        acc[0] += a[t] * b[t];
        acc[1] += a[t + 1] * b[t + 1];
        acc[2] += a[t + 2] * b[t + 2];
        acc[3] += a[t + 3] * b[t + 3];
        t += 4;
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for t in head..n {
        s += a[t] * b[t];
    }
    s
}

/// y = x @ w^T; x: [m, k], w: [n, k] -> [m, n]. Row-parallel.
pub fn matmul_bt(x: &Tensor, w: &Tensor) -> Tensor {
    let (m, k) = x.dims2();
    let (n, k2) = w.dims2();
    assert_eq!(k, k2, "inner dims {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    matmul_bt_into(&x.data, m, k, &w.data, n, &mut out);
    Tensor::new(vec![m, n], out)
}

/// `matmul_bt` into a caller-provided buffer (`out.len() == m * n`). The
/// serving decode loop calls this every step, so no allocation happens
/// here. For the decode shape (m small, n large — e.g. the [b, d] x
/// [vocab, d] logits head at batch 1) the work is parallelized over the
/// `w` rows instead of the `x` rows, which would otherwise leave all but
/// `m` workers idle.
pub fn matmul_bt_into(x: &[f32], m: usize, k: usize, w: &[f32], n: usize, out: &mut [f32]) {
    assert_eq!(x.len(), m * k, "x len vs [{m}, {k}]");
    assert_eq!(w.len(), n * k, "w len vs [{n}, {k}]");
    assert_eq!(out.len(), m * n, "out len vs [{m}, {n}]");
    if m >= crate::util::n_threads() || m >= n {
        parallel_rows(out, n, |i, row| {
            let xi = &x[i * k..(i + 1) * k];
            for (j, o) in row.iter_mut().enumerate() {
                *o = dot_unrolled(xi, &w[j * k..(j + 1) * k]);
            }
        });
        return;
    }
    // Column-parallel: each worker owns a contiguous j-range of weight
    // rows and fills out[i*n + j] for all i. Writes are disjoint per j, so
    // the raw-pointer fan-out (same idiom as hostfwd attention) is sound.
    let out_ptr = out.as_ptr() as usize;
    let total = out.len();
    parallel_chunks(n, |_, s0, e0| {
        let o = unsafe { std::slice::from_raw_parts_mut(out_ptr as *mut f32, total) };
        for j in s0..e0 {
            let wj = &w[j * k..(j + 1) * k];
            for i in 0..m {
                o[i * n + j] = dot_unrolled(&x[i * k..(i + 1) * k], wj);
            }
        }
    });
}

/// a @ b; a: [m, k], b: [k, n].
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (k2, n) = b.dims2();
    assert_eq!(k, k2);
    let mut out = vec![0.0f32; m * n];
    parallel_rows(&mut out, n, |i, row| {
        let ai = &a.data[i * k..(i + 1) * k];
        for (t, &av) in ai.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let bt = &b.data[t * n..(t + 1) * n];
            for (o, bv) in row.iter_mut().zip(bt) {
                *o += av * bv;
            }
        }
    });
    Tensor::new(vec![m, n], out)
}

/// Gram matrix x^T x in f64; x: [m, k] -> [k, k] (GPTQ Hessian).
pub fn gram_f64(x: &Tensor) -> Vec<f64> {
    let (m, k) = x.dims2();
    let nt = crate::util::n_threads();
    let partials = std::sync::Mutex::new(vec![vec![0.0f64; k * k]; 0]);
    parallel_chunks(m, |_, start, end| {
        let mut acc = vec![0.0f64; k * k];
        for i in start..end {
            let xi = &x.data[i * k..(i + 1) * k];
            for a in 0..k {
                let xa = xi[a] as f64;
                if xa == 0.0 {
                    continue;
                }
                let row = &mut acc[a * k..(a + 1) * k];
                for (rv, &xb) in row.iter_mut().zip(xi.iter()) {
                    *rv += xa * xb as f64;
                }
            }
        }
        partials.lock().unwrap().push(acc);
    });
    let _ = nt;
    let mut h = vec![0.0f64; k * k];
    for p in partials.into_inner().unwrap() {
        for (hv, pv) in h.iter_mut().zip(p) {
            *hv += pv;
        }
    }
    h
}

/// In-place lower Cholesky of an n x n SPD matrix (row-major f64).
/// Returns Err(pivot) on a non-positive pivot.
pub fn cholesky_inplace(a: &mut [f64], n: usize) -> Result<(), usize> {
    for j in 0..n {
        let mut d = a[j * n + j];
        for t in 0..j {
            d -= a[j * n + t] * a[j * n + t];
        }
        if d <= 0.0 {
            return Err(j);
        }
        let d = d.sqrt();
        a[j * n + j] = d;
        for i in (j + 1)..n {
            let mut s = a[i * n + j];
            for t in 0..j {
                s -= a[i * n + t] * a[j * n + t];
            }
            a[i * n + j] = s / d;
        }
        for t in (j + 1)..n {
            a[j * n + t] = 0.0; // zero the upper triangle
        }
    }
    Ok(())
}

/// Inverse of an SPD matrix from its Cholesky factor (a = L L^T).
/// `l` is the lower factor as produced by `cholesky_inplace`.
pub fn spd_inverse_from_cholesky(l: &[f64], n: usize) -> Vec<f64> {
    // Solve L L^T X = I column by column.
    let mut inv = vec![0.0f64; n * n];
    let mut col = vec![0.0f64; n];
    for c in 0..n {
        // forward: L y = e_c
        for i in 0..n {
            let mut s = if i == c { 1.0 } else { 0.0 };
            for t in 0..i {
                s -= l[i * n + t] * col[t];
            }
            col[i] = s / l[i * n + i];
        }
        // backward: L^T x = y
        for i in (0..n).rev() {
            let mut s = col[i];
            for t in (i + 1)..n {
                s -= l[t * n + i] * col[t];
            }
            col[i] = s / l[i * n + i];
        }
        for i in 0..n {
            inv[i * n + c] = col[i];
        }
    }
    inv
}

/// In-place normalized fast Walsh-Hadamard transform over the last-dim
/// blocks of length `n` (power of two). H/sqrt(n) is orthonormal, so
/// applying it twice is the identity.
pub fn hadamard_inplace(data: &mut [f32], n: usize) {
    assert!(n.is_power_of_two(), "hadamard dim {n} not a power of two");
    assert_eq!(data.len() % n, 0);
    let norm = 1.0 / (n as f32).sqrt();
    for chunk in data.chunks_mut(n) {
        let mut h = 1;
        while h < n {
            let step = h * 2;
            for i in (0..n).step_by(step) {
                for j in i..i + h {
                    let a = chunk[j];
                    let b = chunk[j + h];
                    chunk[j] = a + b;
                    chunk[j + h] = a - b;
                }
            }
            h = step;
        }
        for v in chunk.iter_mut() {
            *v *= norm;
        }
    }
}

/// Random-sign diagonal composed with Hadamard: x -> H (d .* x), the
/// QuaRot-style randomized orthogonal rotation. `signs` entries are +-1.
pub fn signed_hadamard_inplace(data: &mut [f32], signs: &[f32]) {
    let n = signs.len();
    assert_eq!(data.len() % n, 0);
    for chunk in data.chunks_mut(n) {
        for (v, s) in chunk.iter_mut().zip(signs) {
            *v *= s;
        }
    }
    hadamard_inplace(data, n);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg32;

    #[test]
    fn matmul_bt_matches_naive() {
        let mut rng = Pcg32::seeded(0);
        let x = Tensor::randn(&[5, 7], 1.0, &mut rng);
        let w = Tensor::randn(&[3, 7], 1.0, &mut rng);
        let y = matmul_bt(&x, &w);
        for i in 0..5 {
            for j in 0..3 {
                let want: f32 = (0..7).map(|t| x.data[i * 7 + t] * w.data[j * 7 + t]).sum();
                assert!((y.data[i * 3 + j] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn matmul_agrees_with_bt() {
        let mut rng = Pcg32::seeded(1);
        let a = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let b = Tensor::randn(&[6, 5], 1.0, &mut rng);
        let y1 = matmul(&a, &b);
        let y2 = matmul_bt(&a, &b.transpose2d());
        for (u, v) in y1.data.iter().zip(&y2.data) {
            assert!((u - v).abs() < 1e-4);
        }
    }

    #[test]
    fn cholesky_recomposes() {
        let n = 6;
        let mut rng = Pcg32::seeded(2);
        let x = Tensor::randn(&[12, n], 1.0, &mut rng);
        let mut h = gram_f64(&x);
        for i in 0..n {
            h[i * n + i] += 0.1; // damping
        }
        let orig = h.clone();
        cholesky_inplace(&mut h, n).unwrap();
        // L L^T == orig
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for t in 0..n {
                    s += h[i * n + t] * h[j * n + t];
                }
                assert!((s - orig[i * n + j]).abs() < 1e-8, "{i},{j}");
            }
        }
    }

    #[test]
    fn spd_inverse_is_inverse() {
        let n = 5;
        let mut rng = Pcg32::seeded(3);
        let x = Tensor::randn(&[20, n], 1.0, &mut rng);
        let mut h = gram_f64(&x);
        for i in 0..n {
            h[i * n + i] += 0.5;
        }
        let orig = h.clone();
        cholesky_inplace(&mut h, n).unwrap();
        let inv = spd_inverse_from_cholesky(&h, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for t in 0..n {
                    s += orig[i * n + t] * inv[t * n + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-6, "{i},{j}: {s}");
            }
        }
    }

    #[test]
    fn hadamard_involution_and_norm() {
        let mut rng = Pcg32::seeded(4);
        let orig: Vec<f32> = (0..32).map(|_| rng.normal() as f32).collect();
        let mut x = orig.clone();
        hadamard_inplace(&mut x, 32);
        let n0: f32 = orig.iter().map(|v| v * v).sum();
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-3, "norm preserved");
        hadamard_inplace(&mut x, 32);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn signed_hadamard_preserves_norm() {
        let mut rng = Pcg32::seeded(5);
        let signs: Vec<f32> =
            (0..16).map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 }).collect();
        let orig: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
        let mut x = orig.clone();
        signed_hadamard_inplace(&mut x, &signs);
        let n0: f32 = orig.iter().map(|v| v * v).sum();
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-3);
    }
}
