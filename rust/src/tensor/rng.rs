//! PCG32 — small, fast, seedable RNG (O'Neill 2014).
//!
//! All synthetic data in the repo (corpora, init, tasks) flows through
//! this generator so every experiment is bit-reproducible from a seed.

#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
    cached_normal: Option<f64>,
}

const MULT: u64 = 6364136223846793005;

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1, cached_normal: None };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u32() as f64) / (u32::MAX as f64 + 1.0)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.uniform() * n as f64) as usize % n.max(1)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        let u1 = (1.0 - self.uniform()).max(1e-12);
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.cached_normal = Some(r * s);
        r * c
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(7);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut rng = Pcg32::seeded(3);
        let w = [0.1, 0.8, 0.1];
        let mut counts = [0usize; 3];
        for _ in 0..2000 {
            counts[rng.weighted(&w)] += 1;
        }
        assert!(counts[1] > counts[0] + counts[2]);
    }

    #[test]
    fn below_in_range() {
        let mut rng = Pcg32::seeded(9);
        for _ in 0..1000 {
            assert!(rng.below(7) < 7);
        }
    }
}
