//! `repro` — launcher CLI for the TesseraQ reproduction.
//!
//! Subcommands (hand-rolled parser: the offline vendor set has no clap):
//!   repro pretrain  --size tiny --steps 300 [--corpus wiki] [--out PATH]
//!   repro calibrate --size tiny --quant W2A16g128 [--method tesseraq]
//!   repro eval      --size tiny [--ckpt PATH] [--quant ...]
//!   repro serve     --size tiny --bits 4 [--batch 16] [--new 64]
//!   repro serve-bench [--size nano] [--bits 16,2,3,4]   artifact-free serving bench
//!   repro serve-load  [--size nano] [--rate 200] [--requests 64]  gateway load test
//!   repro table N   [--fast]       regenerate paper table N
//!   repro figure N  [--fast]       regenerate paper figure N
//!   repro e2e       [--fast]       full train->quantize->eval->serve run
//!   repro all-tables [--fast]      every table + figure
//!   repro calibrate-smoke [...]    artifact-free host-path calibration (CI)
//!   repro trace-summary <run>      render a telemetry trace
//!
//! All subcommands accept `--trace-out DIR` (or `TESSERAQ_TRACE=DIR`) to
//! emit structured JSONL telemetry; see `src/obs/`.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use tesseraq::coordinator::pretrain::{pretrain, PretrainConfig};
use tesseraq::data::CorpusKind;
use tesseraq::eval::Evaluator;
use tesseraq::experiments::methods::{gptq_model, quantize, Method, MethodOpts};
use tesseraq::experiments::{tables, Ctx};
use tesseraq::model::{ModelConfig, Params};
use tesseraq::quant::{GroupScheme, QuantConfig};
use tesseraq::report::results_dir;
use tesseraq::robust::{FaultPlan, RobustConfig};
use tesseraq::serve::ServeModel;
use tesseraq::tensor::Pcg32;
use tesseraq::Engine;

struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

fn parse_args() -> Args {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut it = std::env::args().skip(1).peekable();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            let val = if it.peek().map(|v| !v.starts_with("--")).unwrap_or(false) {
                it.next().unwrap()
            } else {
                "true".to_string()
            };
            flags.insert(name.to_string(), val);
        } else {
            positional.push(a);
        }
    }
    Args { positional, flags }
}

impl Args {
    fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn fast(&self) -> bool {
        self.flag("fast").is_some()
    }

    fn size(&self) -> String {
        self.flag("size").unwrap_or("tiny").to_string()
    }

    fn corpus_kind(&self) -> CorpusKind {
        match self.flag("corpus").unwrap_or("wiki") {
            "c4" => CorpusKind::C4Like,
            _ => CorpusKind::WikiLike,
        }
    }
}

fn parse_method(s: &str) -> Result<Method> {
    Ok(match s.to_lowercase().as_str() {
        "rtn" => Method::Rtn,
        "gptq" => Method::Gptq,
        "awq" => Method::Awq,
        "omniquant" | "lwc" => Method::OmniQuant,
        "tesseraq" => Method::TesseraQ,
        "tesseraq-lwc" => Method::TesseraQLwc,
        "smoothquant" => Method::SmoothQuant,
        "quarot" => Method::QuaRot,
        "quarot-gptq" => Method::QuaRotGptq,
        "quarot-tesseraq" => Method::QuaRotTesseraQ,
        other => bail!("unknown method {other:?}"),
    })
}

/// Build the resilience config from `--checkpoint-dir`, `--resume` and
/// `--inject-faults` (the latter also honours `TESSERAQ_FAULTS`).
fn robust_opts(args: &Args) -> Result<RobustConfig> {
    let mut robust = RobustConfig::default();
    if let Some(dir) = args.flag("checkpoint-dir") {
        robust.checkpoint_dir = Some(std::path::PathBuf::from(dir));
    }
    if args.flag("resume").is_some() {
        robust.resume = true;
        if robust.checkpoint_dir.is_none() {
            bail!("--resume requires --checkpoint-dir");
        }
    }
    if let Some(spec) = args.flag("inject-faults") {
        let plan = FaultPlan::parse(spec)
            .with_context(|| format!("parsing --inject-faults {spec:?}"))?;
        robust.faults = Some(std::rc::Rc::new(plan));
    } else {
        robust.faults = FaultPlan::from_env();
    }
    Ok(robust)
}

fn main() -> Result<()> {
    let args = parse_args();
    // Arm the telemetry sink before any work: --trace-out wins, else the
    // TESSERAQ_TRACE env var. Shutdown (final metric flush) runs on both
    // the success and the error path.
    if let Some(dir) = args.flag("trace-out") {
        tesseraq::obs::init(dir)?;
    } else {
        tesseraq::obs::init_from_env()?;
    }
    let res = dispatch(&args);
    tesseraq::obs::shutdown();
    res
}

fn dispatch(args: &Args) -> Result<()> {
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "pretrain" => cmd_pretrain(args),
        "calibrate" => cmd_calibrate(args),
        "calibrate-smoke" => cmd_calibrate_smoke(args),
        "trace-summary" => {
            let path = args.positional.get(1).context("trace-summary <run-dir|trace.jsonl>")?;
            let s = tesseraq::obs::summary::render_summary(std::path::Path::new(path))?;
            println!("{s}");
            Ok(())
        }
        "eval" => cmd_eval(args),
        "serve" => cmd_serve(args),
        "serve-bench" => cmd_serve_bench(args),
        "serve-load" => cmd_serve_load(args),
        "table" => {
            let id: u32 = args.positional.get(1).context("table N")?.parse()?;
            let mut ctx = Ctx::new(args.fast())?;
            ctx.robust = robust_opts(args)?;
            tables::run_table(&ctx, id)
        }
        "figure" => {
            let id: u32 = args.positional.get(1).context("figure N")?.parse()?;
            let mut ctx = Ctx::new(args.fast())?;
            ctx.robust = robust_opts(args)?;
            tables::run_figure(&ctx, id)
        }
        "all-tables" => {
            let mut ctx = Ctx::new(args.fast())?;
            ctx.robust = robust_opts(args)?;
            for id in [1, 2, 3, 4, 5, 6, 7, 8, 10, 11] {
                println!("==== table {id} ====");
                tables::run_table(&ctx, id)?;
            }
            for id in [2, 3, 4] {
                println!("==== figure {id} ====");
                tables::run_figure(&ctx, id)?;
            }
            Ok(())
        }
        "e2e" => cmd_e2e(args),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "repro — TesseraQ reproduction launcher
  pretrain  --size S --steps N [--corpus wiki|c4] [--out PATH]
  calibrate --size S --quant W2A16g128 [--method tesseraq] [--ckpt PATH]
            [--checkpoint-dir DIR] [--resume] [--inject-faults SPEC]
  calibrate-smoke [--size nano] [--quant W2A16g32] [--n-seq 2] [--seq-len 16]
            host-path GPTQ calibration on a fresh random-init model;
            needs no compiled artifacts — for CI and telemetry smoke runs
  trace-summary <run-dir|trace.jsonl>
            render self-time profile + per-block loss table from a trace
  eval      --size S [--ckpt PATH] [--corpus wiki|c4]
  serve     --size S --bits 2|3|4 [--batch B] [--new N]
  serve-bench [--size nano] [--bits 16,2,3,4] [--batch 4] [--prompt 16] [--new 32]
            artifact-free serving benchmark on a random-init model with
            host-side RTN packing; ragged prompts exercise the padded
            decode path; writes results/BENCH_serve.json
            (TESSERAQ_BENCH_MS sets the per-case measurement budget)
  serve-load [--size nano] [--bits 16] [--requests 64] [--rate 200]
            [--deadline 2000] [--queue 32] [--batch 4] [--kv-budget 4096]
            [--prompt 8] [--new 8] [--seed N]
            open-loop load test against the serving gateway (seeded
            Poisson arrivals); reports p50/p95/p99 latency, shed and
            deadline-miss rates, goodput; writes results/BENCH_gateway.json
            (--deadline 0 disables deadlines; faults via TESSERAQ_FAULTS)
  table N   [--fast]        regenerate paper table N (1-12)
  figure N  [--fast]        regenerate paper figure N (2-4)
  all-tables [--fast]
  e2e       [--fast]        full train -> quantize -> eval -> serve

telemetry (all subcommands):
  --trace-out DIR        write structured JSONL telemetry to DIR/trace.jsonl
                         (appends across runs; DIR/manifest.json indexes runs)
                         env equivalent: TESSERAQ_TRACE=DIR

resilience (calibrate, calibrate-smoke, table, figure, all-tables):
  --checkpoint-dir DIR   persist per-block calibration checkpoints to DIR
                         (each method/config gets its own subdirectory)
  --resume               resume a partial run from --checkpoint-dir
  --inject-faults SPEC   deterministic faults, e.g.
                         'nan@0.3,compile@block_par_step:2,kill@1'
                         (also honoured via TESSERAQ_FAULTS env var)";

fn cmd_pretrain(args: &Args) -> Result<()> {
    let eng = Engine::from_default_dir()?;
    let size = args.size();
    let cfg = ModelConfig::preset(&size)?;
    let kind = args.corpus_kind();
    let corpus = tesseraq::data::Corpus::new(kind, cfg.vocab_size);
    let steps: usize = args.flag("steps").unwrap_or("300").parse()?;
    let mut rng = Pcg32::seeded(42);
    let mut params = Params::init(&cfg, &mut rng);
    let pcfg = PretrainConfig { steps, ..Default::default() };
    println!(
        "pretraining {size} ({:.2}M params) on {} for {steps} steps",
        cfg.param_count() as f64 / 1e6,
        kind.name()
    );
    let rep = pretrain(&eng, &mut params, &corpus, &pcfg, |s, l| {
        println!("  step {s:>5}  loss {l:.4}");
    })?;
    let out = args
        .flag("out")
        .map(Into::into)
        .unwrap_or_else(|| results_dir().join("ckpt").join(format!("{size}.{}.cli.tsq", kind.name())));
    params.save(&out)?;
    println!(
        "done in {:.1}s (final loss {:.4}); saved {}",
        rep.wall_s,
        rep.losses.last().copied().unwrap_or(f32::NAN),
        out.display()
    );
    Ok(())
}

fn load_or_train(args: &Args, ctx: &Ctx, size: &str) -> Result<Params> {
    if let Some(p) = args.flag("ckpt") {
        return Params::load(std::path::Path::new(p));
    }
    ctx.base_model(size, args.corpus_kind())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let ctx = Ctx::new(args.fast())?;
    let size = args.size();
    let qcfg = QuantConfig::parse(args.flag("quant").unwrap_or("W2A16g128"))?;
    let method = parse_method(args.flag("method").unwrap_or("tesseraq"))?;
    let base = load_or_train(args, &ctx, &size)?;
    let calib = ctx.corpus(args.corpus_kind(), &size)?;
    let mut opts = MethodOpts::new(qcfg, ctx.n_calib(), ctx.fast);
    opts.robust = robust_opts(args)?;
    println!("calibrating {size} with {} at {}", method.label(), qcfg.label());
    let t0 = std::time::Instant::now();
    let q = quantize(&ctx.eng, &base, method, &qcfg, &calib, &opts)?;
    println!("calibration done in {:.1}s", t0.elapsed().as_secs_f64());
    let ev = Evaluator::new(&ctx.eng, &size)?;
    let wiki = ctx.corpus(CorpusKind::WikiLike, &size)?;
    let ppl = ev.perplexity(&q.params, q.head_t.as_ref(), qcfg.qmax_act(), &wiki,
                            ctx.n_eval(), 0xEA1)?;
    println!("wiki-like PPL: {ppl:.3}");
    let out = args
        .flag("out")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            results_dir().join("ckpt").join(format!("{size}.{}.{}.tsq", method.label(), qcfg.label()))
        });
    q.params.save(&out)?;
    println!("saved {}", out.display());
    Ok(())
}

/// Artifact-free calibration smoke: host-path GPTQ on a fresh random-init
/// model through the unified reconstruction driver. Needs no compiled
/// artifact directory, so CI can exercise the robust + telemetry layers
/// (checkpoints, fault injection, resume, traces) with this command alone.
fn cmd_calibrate_smoke(args: &Args) -> Result<()> {
    let size = args.flag("size").unwrap_or("nano").to_string();
    let cfg = ModelConfig::preset(&size)?;
    let qcfg = QuantConfig::parse(args.flag("quant").unwrap_or("W2A16g32"))?;
    let n_seq: usize = args.flag("n-seq").unwrap_or("2").parse()?;
    let seq_len: usize = args.flag("seq-len").unwrap_or("16").parse()?;
    if n_seq == 0 || seq_len == 0 || seq_len > cfg.max_seq {
        bail!("need n_seq >= 1 and 1 <= seq_len <= {}", cfg.max_seq);
    }
    let robust = robust_opts(args)?;
    let mut rng = Pcg32::seeded(0x5EED);
    let mut params = Params::init(&cfg, &mut rng);
    let tokens: Vec<i32> = (0..n_seq * seq_len)
        .map(|i| ((i * 17 + 3) % cfg.vocab_size) as i32)
        .collect();
    println!(
        "calibrate-smoke: {size} gptq at {} ({n_seq}x{seq_len} tokens)",
        qcfg.label()
    );
    let report = gptq_model(None, &mut params, &tokens, n_seq, &qcfg, &robust)?;
    let fb = report.fallback_blocks();
    println!(
        "done: {} blocks in {:.2}s{}",
        report.per_block.len(),
        report.wall_s,
        if fb.is_empty() { String::new() } else { format!(" (RTN fallback: {fb:?})") }
    );
    match tesseraq::report::write_json("calib_smoke", &report.to_json()) {
        Ok(p) => println!("report: {}", p.display()),
        Err(e) => tesseraq::obs::warn(
            "report_write_failed",
            &format!("[report] could not write calib_smoke.json: {e:#}"),
            &[("report", "calib_smoke".into()), ("error", format!("{e:#}").into())],
        ),
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let ctx = Ctx::new(args.fast())?;
    let size = args.size();
    let params = load_or_train(args, &ctx, &size)?;
    let ev = Evaluator::new(&ctx.eng, &size)?;
    for kind in [CorpusKind::WikiLike, CorpusKind::C4Like] {
        let corpus = ctx.corpus(kind, &size)?;
        let ppl = ev.perplexity(&params, None, 65535.0, &corpus, ctx.n_eval(), 0xEA1)?;
        println!("{} PPL: {ppl:.3}", kind.name());
    }
    let wiki = ctx.corpus(CorpusKind::WikiLike, &size)?;
    for (name, acc) in ev.zeroshot_suite(&params, None, 65535.0, &wiki, ctx.n_items(), 24)? {
        println!("{name:>10}: {:.2}%", acc * 100.0);
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let ctx = Ctx::new(args.fast())?;
    let size = args.size();
    let base = load_or_train(args, &ctx, &size)?;
    let calib = ctx.corpus(CorpusKind::WikiLike, &size)?;
    let batch: usize = args.flag("batch").unwrap_or("4").parse()?;
    let max_new: usize = args.flag("new").unwrap_or("64").parse()?;
    let bits: u32 = args.flag("bits").unwrap_or("4").parse()?;
    let model = if bits >= 16 {
        ServeModel::dense(&base)
    } else {
        let qcfg = QuantConfig::weight_only(bits, GroupScheme::Group(128));
        let opts = MethodOpts::new(qcfg, ctx.n_calib(), ctx.fast);
        let q = quantize(&ctx.eng, &base, Method::TesseraQ, &qcfg, &calib, &opts)?;
        let report =
            q.report.as_ref().context("TesseraQ quantize produced no calibration report")?;
        ServeModel::packed(&q.params, report, bits)?
    };
    let prompts: Vec<Vec<i32>> = (0..batch).map(|i| calib.sample(16, i as u64)).collect();
    let (outs, stats) = model.generate(&prompts, max_new)?;
    println!(
        "{}: batch={} weight_mem={} decode={:.1} tok/s prefill={:.1} tok/s",
        stats.label,
        stats.batch,
        tesseraq::report::fmt_bytes(stats.weight_bytes),
        stats.tokens_per_s,
        stats.prefill_tokens_per_s
    );
    println!("sample continuation: {:?}", &outs[0][..outs[0].len().min(16)]);
    Ok(())
}

/// Artifact-free serving benchmark: random-init weights, host-side RTN
/// packing — measures the ragged-batch serve hot path (batched vs
/// per-token prefill, steady-state decode) for dense and packed models
/// and writes results/BENCH_serve.json. Runs anywhere (CI included):
/// kernel throughput does not depend on how the codes were calibrated.
fn cmd_serve_bench(args: &Args) -> Result<()> {
    use std::collections::BTreeMap;
    use tesseraq::serve::PrefillMode;
    use tesseraq::util::bench::Bench;
    use tesseraq::util::json::Json;

    let size = args.flag("size").unwrap_or("nano").to_string();
    let cfg = ModelConfig::preset(&size)?;
    let batch: usize = args.flag("batch").unwrap_or("4").parse()?;
    let prompt_len: usize = args.flag("prompt").unwrap_or("16").parse()?;
    let max_new: usize = args.flag("new").unwrap_or("32").parse()?;
    let bits_list: Vec<u32> = args
        .flag("bits")
        .unwrap_or("16,2,3,4")
        .split(',')
        .map(|s| s.trim().parse())
        .collect::<Result<_, _>>()?;
    if batch == 0 || prompt_len < 2 || max_new == 0 {
        bail!("serve-bench needs batch >= 1, prompt >= 2, new >= 1");
    }

    let mut rng = Pcg32::seeded(0xBE7C);
    let params = Params::init(&cfg, &mut rng);
    // ragged on purpose: odd rows get half-length prompts so the bench
    // exercises the padding/masking path, not just the aligned one
    let prompts: Vec<Vec<i32>> = (0..batch)
        .map(|r| {
            let len = if r % 2 == 1 { (prompt_len / 2).max(1) } else { prompt_len };
            (0..len).map(|_| rng.below(cfg.vocab_size) as i32).collect()
        })
        .collect();
    let plens: Vec<usize> = prompts.iter().map(|p| p.len()).collect();

    println!(
        "serve-bench: {size} batch={batch} prompts={plens:?} new={max_new} threads={}",
        tesseraq::util::n_threads()
    );
    let mut b = Bench::new("serve");
    let mut cases = Vec::new();
    for &bits in &bits_list {
        let model = if bits >= 16 {
            ServeModel::dense(&params)
        } else {
            ServeModel::packed_rtn(&params, bits)?
        };
        // one checked run per prefill mode: surfaces errors and records
        // stats before the timing loop discards results
        let (_, st_b) = model.generate_with(&prompts, max_new, PrefillMode::Batched)?;
        let (_, st_t) = model.generate_with(&prompts, max_new, PrefillMode::PerToken)?;
        let rec = b.iter(&model.label, || {
            let _ = std::hint::black_box(model.generate(&prompts, max_new));
        });
        println!(
            "{:>12}: {} weights, decode {:.1} tok/s, prefill {:.1} vs {:.1} tok/s",
            st_b.label,
            tesseraq::report::fmt_bytes(st_b.weight_bytes),
            st_b.tokens_per_s,
            st_b.prefill_tokens_per_s,
            st_t.prefill_tokens_per_s,
        );
        let mut c = BTreeMap::new();
        c.insert("label".to_string(), Json::Str(st_b.label.clone()));
        c.insert("bits".to_string(), Json::Num(bits as f64));
        c.insert("weight_bytes".to_string(), Json::Num(st_b.weight_bytes as f64));
        c.insert("decode_tok_s".to_string(), Json::Num(st_b.tokens_per_s));
        c.insert(
            "prefill_tok_s_batched".to_string(),
            Json::Num(st_b.prefill_tokens_per_s),
        );
        c.insert(
            "prefill_tok_s_per_token".to_string(),
            Json::Num(st_t.prefill_tokens_per_s),
        );
        c.insert("generate_mean_ns".to_string(), Json::Num(rec.mean_ns));
        c.insert("generate_p50_ns".to_string(), Json::Num(rec.p50_ns));
        c.insert("generate_p95_ns".to_string(), Json::Num(rec.p95_ns));
        c.insert("iters".to_string(), Json::Num(rec.iters as f64));
        cases.push(Json::Obj(c));
    }
    b.report();

    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("serve".to_string()));
    top.insert("size".to_string(), Json::Str(size.clone()));
    top.insert("batch".to_string(), Json::Num(batch as f64));
    top.insert(
        "prompt_lens".to_string(),
        Json::Arr(plens.iter().map(|&l| Json::Num(l as f64)).collect()),
    );
    top.insert("new_tokens".to_string(), Json::Num(max_new as f64));
    top.insert("threads".to_string(), Json::Num(tesseraq::util::n_threads() as f64));
    top.insert("cases".to_string(), Json::Arr(cases));
    let path = tesseraq::report::write_json("BENCH_serve", &Json::Obj(top).dump())?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Open-loop load test against the serving gateway: synthetic Poisson
/// arrivals from a seeded RNG are submitted at their scheduled times
/// (arrivals do not wait for the server — that is what makes overload
/// visible), the gateway pumps between arrivals, and the terminal
/// outcomes become results/BENCH_gateway.json: p50/p95/p99 completion
/// latency, shed rate, deadline-miss rate, and goodput (completed tokens
/// per wall second). Artifact-free (dense or host-side RTN packing) so
/// CI can run it; `TESSERAQ_FAULTS` request-level kinds turn it into a
/// chaos drill.
fn cmd_serve_load(args: &Args) -> Result<()> {
    use std::collections::BTreeMap;
    use tesseraq::serve::{Gateway, GatewayConfig, Request};
    use tesseraq::util::json::Json;

    let size = args.flag("size").unwrap_or("nano").to_string();
    let cfg = ModelConfig::preset(&size)?;
    let bits: u32 = args.flag("bits").unwrap_or("16").parse()?;
    let n_requests: usize = args.flag("requests").unwrap_or("64").parse()?;
    let rate: f64 = args.flag("rate").unwrap_or("200").parse()?;
    let deadline_ms: u64 = args.flag("deadline").unwrap_or("2000").parse()?;
    let queue_depth: usize = args.flag("queue").unwrap_or("32").parse()?;
    let batch: usize = args.flag("batch").unwrap_or("4").parse()?;
    let kv_budget: usize = args.flag("kv-budget").unwrap_or("4096").parse()?;
    let prompt_len: usize = args.flag("prompt").unwrap_or("8").parse()?;
    let max_new: usize = args.flag("new").unwrap_or("8").parse()?;
    let seed: u64 = args.flag("seed").unwrap_or("42").parse()?;
    if n_requests == 0 || rate <= 0.0 || batch == 0 || prompt_len == 0 || max_new == 0 {
        bail!("serve-load needs requests/rate/batch/prompt/new all >= 1");
    }

    let mut rng = Pcg32::seeded(seed);
    let params = Params::init(&cfg, &mut rng);
    let model = if bits >= 16 {
        ServeModel::dense(&params)
    } else {
        ServeModel::packed_rtn(&params, bits)?
    };

    // open-loop arrival schedule: exponential interarrivals at `rate`
    // req/s, ragged prompt lengths in [prompt/2, prompt]
    let mut arrivals: Vec<(u64, Vec<i32>)> = Vec::with_capacity(n_requests);
    let mut t_ms = 0.0f64;
    for _ in 0..n_requests {
        let u = rng.uniform();
        t_ms += -(1.0 - u).ln() * 1000.0 / rate;
        let len = (prompt_len / 2).max(1) + rng.below(prompt_len / 2 + 1);
        let prompt: Vec<i32> =
            (0..len).map(|_| rng.below(cfg.vocab_size) as i32).collect();
        arrivals.push((t_ms as u64, prompt));
    }

    let gw_cfg = GatewayConfig {
        queue_depth,
        max_batch: batch,
        kv_slot_budget: kv_budget,
        default_deadline_ms: if deadline_ms == 0 { None } else { Some(deadline_ms) },
        ..Default::default()
    };
    let mut gw = Gateway::new(&model, gw_cfg);
    if let Some(plan) = FaultPlan::from_env() {
        gw = gw.with_faults(plan);
    }

    println!(
        "serve-load: {size} {} rate={rate} req/s requests={n_requests} deadline={deadline_ms}ms \
         queue={queue_depth} batch={batch} kv-budget={kv_budget}",
        model.label
    );
    let t0 = std::time::Instant::now();
    let mut next = 0usize;
    loop {
        let now = gw.now_ms();
        while next < arrivals.len() && arrivals[next].0 <= now {
            let (_, prompt) = &arrivals[next];
            let _ = gw.submit(Request::new(prompt.clone(), max_new));
            next += 1;
        }
        if gw.idle() {
            if next >= arrivals.len() {
                break;
            }
            // nothing in flight: skip synthetic time to the next arrival
            let gap = arrivals[next].0.saturating_sub(now);
            gw.advance_ms(gap.max(1));
            continue;
        }
        gw.step();
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    let c = gw.counters().clone();
    if c.admitted != c.completed + c.deadline_missed + c.failed {
        bail!(
            "request conservation violated: admitted {} != {} + {} + {}",
            c.admitted,
            c.completed,
            c.deadline_missed,
            c.failed
        );
    }
    if gw.kv_in_use() != 0 {
        bail!("KV ledger leaked {} slot-units after drain", gw.kv_in_use());
    }
    let mut latencies: Vec<u64> = Vec::new();
    let mut tokens_out = 0usize;
    for out in gw.outcomes().values() {
        if let tesseraq::serve::RequestOutcome::Completed { tokens, latency_ms, .. } = out {
            latencies.push(*latency_ms);
            tokens_out += tokens.len();
        }
    }
    latencies.sort_unstable();
    let pct = |p: f64| -> f64 {
        if latencies.is_empty() {
            return 0.0;
        }
        let idx = ((p / 100.0) * (latencies.len() - 1) as f64).round() as usize;
        latencies[idx] as f64
    };
    let frac = |n: u64| if c.submitted == 0 { 0.0 } else { n as f64 / c.submitted as f64 };
    let goodput = tokens_out as f64 / (wall_ms / 1e3).max(1e-9);

    println!(
        "done in {:.0}ms: {}/{} completed ({} shed, {} deadline-missed, {} failed, {} degraded)",
        wall_ms, c.completed, c.submitted, c.shed, c.deadline_missed, c.failed, c.degraded
    );
    println!(
        "latency p50/p95/p99 = {:.0}/{:.0}/{:.0} ms, goodput {:.1} tok/s, kv peak {}",
        pct(50.0),
        pct(95.0),
        pct(99.0),
        goodput,
        gw.kv_peak()
    );

    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("gateway".to_string()));
    top.insert("size".to_string(), Json::Str(size.clone()));
    top.insert("label".to_string(), Json::Str(model.label.clone()));
    top.insert("bits".to_string(), Json::Num(bits as f64));
    top.insert("requests".to_string(), Json::Num(n_requests as f64));
    top.insert("rate_req_s".to_string(), Json::Num(rate));
    top.insert("deadline_ms".to_string(), Json::Num(deadline_ms as f64));
    top.insert("queue_depth".to_string(), Json::Num(queue_depth as f64));
    top.insert("batch".to_string(), Json::Num(batch as f64));
    top.insert("kv_slot_budget".to_string(), Json::Num(kv_budget as f64));
    top.insert("max_new".to_string(), Json::Num(max_new as f64));
    top.insert("seed".to_string(), Json::Num(seed as f64));
    top.insert("threads".to_string(), Json::Num(tesseraq::util::n_threads() as f64));
    top.insert("submitted".to_string(), Json::Num(c.submitted as f64));
    top.insert("admitted".to_string(), Json::Num(c.admitted as f64));
    top.insert("shed".to_string(), Json::Num(c.shed as f64));
    top.insert("completed".to_string(), Json::Num(c.completed as f64));
    top.insert("deadline_missed".to_string(), Json::Num(c.deadline_missed as f64));
    top.insert("failed".to_string(), Json::Num(c.failed as f64));
    top.insert("degraded".to_string(), Json::Num(c.degraded as f64));
    top.insert("requeued".to_string(), Json::Num(c.requeued as f64));
    top.insert("shed_rate".to_string(), Json::Num(frac(c.shed)));
    top.insert("deadline_miss_rate".to_string(), Json::Num(frac(c.deadline_missed)));
    top.insert("latency_ms_p50".to_string(), Json::Num(pct(50.0)));
    top.insert("latency_ms_p95".to_string(), Json::Num(pct(95.0)));
    top.insert("latency_ms_p99".to_string(), Json::Num(pct(99.0)));
    top.insert("goodput_tok_s".to_string(), Json::Num(goodput));
    top.insert("wall_ms".to_string(), Json::Num(wall_ms));
    top.insert("kv_peak".to_string(), Json::Num(gw.kv_peak() as f64));
    let path = tesseraq::report::write_json("BENCH_gateway", &Json::Obj(top).dump())?;
    println!("wrote {}", path.display());
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    // the full story: train -> FP eval -> RTN/AWQ/TesseraQ -> eval -> serve
    let ctx = Ctx::new(args.fast())?;
    let size = args.size();
    println!("== E2E: {size} ==");
    let base = ctx.base_model(&size, CorpusKind::WikiLike)?;
    let calib = ctx.corpus(CorpusKind::WikiLike, &size)?;
    let ev = Evaluator::new(&ctx.eng, &size)?;
    let wiki = ctx.corpus(CorpusKind::WikiLike, &size)?;
    let qcfg = QuantConfig::weight_only(2, GroupScheme::Group(64));

    let ppl_fp = ev.perplexity(&base, None, 65535.0, &wiki, ctx.n_eval(), 0xEA1)?;
    println!("FP16 wiki-like PPL: {ppl_fp:.3}");

    let mut lines = vec![format!("| FP16 | {ppl_fp:.3} | - |")];
    for m in [Method::Rtn, Method::Awq, Method::TesseraQ] {
        let opts = MethodOpts::new(qcfg, ctx.n_calib(), ctx.fast);
        let t0 = std::time::Instant::now();
        let q = quantize(&ctx.eng, &base, m, &qcfg, &calib, &opts)?;
        let ppl = ev.perplexity(&q.params, q.head_t.as_ref(), qcfg.qmax_act(), &wiki,
                                ctx.n_eval(), 0xEA1)?;
        println!("{} {} PPL: {ppl:.3} ({:.1}s)", m.label(), qcfg.label(),
                 t0.elapsed().as_secs_f64());
        lines.push(format!("| {} | {ppl:.3} | {:.1}s |", m.label(),
                           t0.elapsed().as_secs_f64()));
        if m == Method::TesseraQ {
            let report =
                q.report.as_ref().context("TesseraQ quantize produced no calibration report")?;
            let packed = ServeModel::packed(&q.params, report, qcfg.w_bits)?;
            let prompts: Vec<Vec<i32>> = (0..4).map(|i| calib.sample(16, i as u64)).collect();
            let (_, stats) = packed.generate(&prompts, 32)?;
            println!(
                "packed W{} serve: {} weight mem, {:.1} tok/s",
                qcfg.w_bits,
                tesseraq::report::fmt_bytes(stats.weight_bytes),
                stats.tokens_per_s
            );
        }
    }
    tesseraq::report::append_log(
        "e2e.md",
        &format!("## e2e {size} {}\n| method | PPL | time |\n|---|---|---|\n{}\n",
                 qcfg.label(), lines.join("\n")),
    )?;
    Ok(())
}
