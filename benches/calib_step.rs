//! Calibration hot-loop bench: wall time per block_par_step / block_lwc
//! step / block_fp_fwd artifact call on the tiny model, plus marshalling
//! overhead split (upload/download bytes from EngineStats). Drives the
//! §Perf optimization loop for L2/L3.
//!
//!   cargo bench --bench calib_step

use std::collections::BTreeMap;

use tesseraq::coordinator::pipeline::BlockRunner;
use tesseraq::model::{ModelConfig, Params};
use tesseraq::quant::{self, minmax_scale, nu_init, w_floor, ClipFactors};
use tesseraq::runtime::{Arg, Engine};
use tesseraq::tensor::{Pcg32, Tensor};
use tesseraq::util::bench::Bench;

fn main() -> anyhow::Result<()> {
    let eng = Engine::from_default_dir()?;
    let size = "tiny";
    let cfg = ModelConfig::preset(size)?;
    let mut rng = Pcg32::seeded(0);
    let params = Params::init(&cfg, &mut rng);
    let bw = params.block(0);
    let mut b = Bench::new("calib_step");

    // teacher forward
    let runner = BlockRunner::new(&eng, size)?;
    let x = Tensor::randn(&[runner.batch, cfg.max_seq, cfg.d_model], 1.0, &mut rng);
    b.iter("block_fp_fwd (b4)", || {
        std::hint::black_box(runner.forward_batch(&bw, &x, quant::A16_SENTINEL).unwrap());
    });

    // PAR step
    let art = eng.artifact(&format!("block_par_step.{size}.g128"))?;
    let qmax = 3.0f32;
    let mut state: BTreeMap<&str, (Tensor, Tensor, Tensor, Tensor, Tensor)> = BTreeMap::new();
    for name in tesseraq::model::LINEAR_NAMES {
        let w = &bw.linears[name];
        let g = 128.min(w.shape[1]);
        let qp = minmax_scale(w, g, &ClipFactors::Uniform(1.0),
                              &ClipFactors::Uniform(1.0), qmax);
        let wf = w_floor(w, &qp);
        let nu = nu_init(w, &qp);
        let v = Tensor::zeros(&qp.s.shape);
        state.insert(name, (wf, qp.s, qp.z, nu, v));
    }
    let y = runner.forward_batch(&bw, &x, quant::A16_SENTINEL)?;
    let rec = b.iter("block_par_step (b4, g128)", || {
        let mut args: Vec<Arg> =
            vec![Arg::F32(&x), Arg::F32(&y), Arg::F32(&bw.norm1), Arg::F32(&bw.norm2)];
        for name in tesseraq::model::LINEAR_NAMES {
            let (wf, s, z, _, _) = &state[name];
            args.push(Arg::F32(wf));
            args.push(Arg::F32(s));
            args.push(Arg::F32(z));
        }
        // order: nu, v, m_nu, u_nu, m_v, u_v — m/u zeros share the nu/v
        // shaped tensors for the bench (values don't matter for timing)
        for field in ["nu", "v", "m_nu", "u_nu", "m_v", "u_v"] {
            for name in tesseraq::model::LINEAR_NAMES {
                let (_, _, _, nu, v) = &state[name];
                let is_full = matches!(field, "nu" | "m_nu" | "u_nu");
                args.push(Arg::F32(if is_full { nu } else { v }));
            }
        }
        args.push(Arg::Scalar(1e-2));
        args.push(Arg::Scalar(1.0));
        args.push(Arg::Scalar(qmax));
        args.push(Arg::Scalar(65535.0));
        std::hint::black_box(eng.run(&art, &args).unwrap());
    });

    let stats = eng.stats.borrow().clone();
    println!(
        "\nper-step marshalling: ~{:.1} MB up / {:.1} MB down over {} exec calls",
        stats.upload_bytes as f64 / 1e6 / stats.exec_calls.max(1) as f64,
        stats.download_bytes as f64 / 1e6 / stats.exec_calls.max(1) as f64,
        stats.exec_calls
    );
    println!(
        "estimated full W2 tiny calibration (6 blocks x 8 iters x 24 steps): {:.0}s",
        rec.mean_s() * 6.0 * 8.0 * 24.0
    );
    b.report();
    Ok(())
}
