//! Table 8 bench: end-to-end decode throughput (tokens/s) and weight
//! memory for FP16-dense vs packed W4/W2 serving, batch 1 and 16.
//!
//!   cargo bench --bench table8_throughput
//!
//! The paper's shape to reproduce: INT4 >= FP16 at batch 1 (memory-bound
//! decode), INT2 kernel less optimized; memory ratio exact (16/N bits).

use tesseraq::data::{Corpus, CorpusKind};
use tesseraq::experiments::methods::{quantize, Method, MethodOpts};
use tesseraq::experiments::Ctx;
use tesseraq::quant::{GroupScheme, QuantConfig};
use tesseraq::report::fmt_bytes;
use tesseraq::serve::ServeModel;

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new(true)?;
    let size = "tiny";
    let base = ctx.base_model(size, CorpusKind::WikiLike)?;
    let corpus = Corpus::new(CorpusKind::WikiLike, base.cfg.vocab_size);
    let gen = 32usize;

    println!(
        "{:<12} {:>10} {:>12} {:>12} {:>12}",
        "bitwidth", "WM", "TP_1", "TP_16", "PF_16"
    );
    let mut run = |label: &str, model: &ServeModel| -> anyhow::Result<()> {
        let p1 = vec![corpus.sample(16, 0)];
        let (_, s1) = model.generate(&p1, gen)?;
        let p16: Vec<Vec<i32>> = (0..16).map(|i| corpus.sample(16, i as u64)).collect();
        let (_, s16) = model.generate(&p16, gen)?;
        // TP_n = generated tokens/s (decode loop only, like the paper);
        // PF_16 = prompt tokens/s through the batched prefill
        println!(
            "{:<12} {:>10} {:>12.1} {:>12.1} {:>12.1}",
            label,
            fmt_bytes(model.weight_bytes()),
            s1.tokens_per_s,
            s16.tokens_per_s,
            s16.prefill_tokens_per_s
        );
        Ok(())
    };

    let dense = ServeModel::dense(&base);
    run("FP16", &dense)?;
    for bits in [4u32, 2] {
        let qcfg = QuantConfig::weight_only(bits, GroupScheme::Group(128));
        let opts = MethodOpts::new(qcfg, ctx.n_calib(), true);
        let q = quantize(&ctx.eng, &base, Method::TesseraQ, &qcfg, &corpus, &opts)?;
        let report = q.report.as_ref().expect("TesseraQ report");
        let packed = ServeModel::packed(&q.params, report, bits)?;
        run(&format!("W{bits}A16g128"), &packed)?;
    }
    Ok(())
}
