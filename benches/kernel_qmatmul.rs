//! L1/L3 kernel micro-bench: packed dequant-matmul (Rust serving kernel
//! and the Pallas-lowered artifact) vs dense f32 matmul, across bit
//! widths and batch sizes. Supports the §Perf log and Table 8 analysis.
//!
//!   cargo bench --bench kernel_qmatmul

use tesseraq::model::hostfwd::LinearOp;
use tesseraq::quant::pack::PackedLinear;
use tesseraq::quant::{minmax_scale, rtn_codes, ClipFactors};
use tesseraq::runtime::{Arg, Engine};
use tesseraq::tensor::{linalg, Pcg32, Tensor};
use tesseraq::util::bench::Bench;

fn main() {
    let mut b = Bench::new("qmatmul");
    let mut rng = Pcg32::seeded(0);
    let (o, k, g) = (768, 256, 64); // tiny gate_proj shape
    let w = Tensor::randn(&[o, k], 1.0, &mut rng);

    for m in [1usize, 16, 128] {
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        b.iter(&format!("dense f32 m={m}"), || {
            std::hint::black_box(linalg::matmul_bt(&x, &w));
        });
        for bits in [2u32, 3, 4] {
            let qmax = (2u32.pow(bits) - 1) as f32;
            let qp = minmax_scale(&w, g, &ClipFactors::Uniform(1.0),
                                  &ClipFactors::Uniform(1.0), qmax);
            let codes = rtn_codes(&w, &qp, qmax);
            let pl = PackedLinear::from_codes(&codes, o, k, bits, qp).expect("pack");
            b.iter(&format!("packed w{bits} m={m}"), || {
                std::hint::black_box(pl.forward(&x));
            });
            // the serving hot path: preallocated output, no per-call alloc
            let mut out = vec![0.0f32; m * o];
            b.iter(&format!("packed w{bits} m={m} into"), || {
                pl.forward_into(&x.data, m, &mut out);
                std::hint::black_box(&out);
            });
            if m == 1 {
                // word-at-a-time row decode underlying both paths
                let mut row = vec![0.0f32; k];
                b.iter(&format!("dequant row w{bits}"), || {
                    pl.dequant_row_into(0, &mut row);
                    std::hint::black_box(&row);
                });
            }
        }
    }

    // Pallas-lowered artifact path (interpret-mode kernel compiled by XLA)
    if let Ok(eng) = Engine::from_default_dir() {
        for bits in [2u32, 4] {
            if let Ok(art) = eng.artifact(&format!("qmatmul_w{bits}.tiny")) {
                let spec = art.spec.clone();
                let xs = &spec.inputs[0].shape;
                let ps = &spec.inputs[1].shape;
                let ss = &spec.inputs[2].shape;
                let x = Tensor::randn(xs, 1.0, &mut rng);
                let packed: Vec<i32> =
                    (0..ps.iter().product::<usize>()).map(|_| rng.next_u32() as i32).collect();
                let s = Tensor::full(ss, 0.05);
                let z = Tensor::full(ss, 1.0);
                b.iter(&format!("pallas artifact w{bits} m={}", xs[0]), || {
                    let args = vec![
                        Arg::F32(&x),
                        Arg::I32(&packed, ps),
                        Arg::F32(&s),
                        Arg::F32(&z),
                    ];
                    std::hint::black_box(eng.run(&art, &args).unwrap());
                });
            }
        }
    }
    b.report();
}
