"""L2: LLaMA-architecture decoder graphs and calibration steps (JAX).

Everything here is lowered ONCE by aot.py to HLO text and then driven from
the Rust coordinator; Python never runs on the request path.

Parameter layout contract (mirrored by rust/src/model/params.rs):
full-model parameters are a dict keyed by PARAM_NAMES with *stacked* block
tensors — e.g. params["q_proj"] has shape [n_layers, d_model, d_model].
Artifacts take these tensors as positional inputs in PARAM_NAMES order;
the manifest emitted by aot.py records the exact shapes.

Differentiability: training graphs (par_step / lwc_step / train_step) use
the pure-jnp fake-quant path from quantize.py (pallas_call has no VJP);
inference graphs (block_quant_fwd) route the same math through the Pallas
kernels, and pytest ties the two paths together numerically.
"""

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .configs import LINEAR_NAMES, ModelConfig
from .quantize import act_fakequant, lwc_qdq, soft_qdq
from .kernels.fused_qdq_matmul import fused_qdq_matmul
from .kernels.rmsnorm import rmsnorm as rmsnorm_pallas

# Full-model parameter ordering (the artifact input contract).
PARAM_NAMES: List[str] = ["emb", "norm_f"] + LINEAR_NAMES + ["norm1", "norm2"]

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8
DST_WEIGHT_DECAY = 1e-4  # paper: 1e-4 weight decay on v


def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    shapes = {
        "emb": (cfg.vocab_size, cfg.d_model),
        "norm_f": (cfg.d_model,),
        "norm1": (cfg.n_layers, cfg.d_model),
        "norm2": (cfg.n_layers, cfg.d_model),
    }
    for name, (o, i) in cfg.linear_shapes().items():
        shapes[name] = (cfg.n_layers, o, i)
    return shapes


# ---------------------------------------------------------------------------
# Core ops


def rmsnorm(x, w, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _rope_tables(cfg: ModelConfig, t: int):
    hd = cfg.head_dim
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = jnp.arange(t, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)  # [T, hd/2]


def _apply_rope(x, cos, sin):
    """x: [B, H, T, hd]; rotate-half convention (LLaMA)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[None, None, :, :]
    s = sin[None, None, :, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def _lin3(lin, name, t):
    """Apply a 2-D linear closure to a [..., in] tensor."""
    flat = t.reshape(-1, t.shape[-1])
    out = lin(name, flat)
    return out.reshape(*t.shape[:-1], out.shape[-1])


def block_core(x, n1, n2, lin, cfg: ModelConfig, qmax_act, ste,
               norm_fn=rmsnorm):
    """One decoder block: pre-norm attention + gated MLP, with per-token
    activation fake-quant in front of every linear (paper's A-quant setup).

    `lin(name, h2d)` computes h2d @ W_name.T for whichever weight
    representation (FP / soft-quant / Pallas fused) the caller wires in.
    """
    b, t, d = x.shape
    hdim = cfg.head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads

    h = norm_fn(x, n1)
    hq = act_fakequant(h, qmax_act, ste)
    q = _lin3(lin, "q_proj", hq).reshape(b, t, nh, hdim).transpose(0, 2, 1, 3)
    k = _lin3(lin, "k_proj", hq).reshape(b, t, nkv, hdim).transpose(0, 2, 1, 3)
    v = _lin3(lin, "v_proj", hq).reshape(b, t, nkv, hdim).transpose(0, 2, 1, 3)

    cos, sin = _rope_tables(cfg, t)
    q = _apply_rope(q, cos, sin)
    k = _apply_rope(k, cos, sin)
    if nkv != nh:
        rep = nh // nkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)

    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hdim))
    mask = jnp.tril(jnp.ones((t, t), jnp.float32))
    scores = jnp.where(mask[None, None] > 0, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, t, d)
    ctxq = act_fakequant(ctx, qmax_act, ste)
    x = x + _lin3(lin, "o_proj", ctxq)

    h2 = norm_fn(x, n2)
    h2q = act_fakequant(h2, qmax_act, ste)
    gate = jax.nn.silu(_lin3(lin, "gate_proj", h2q))
    up = _lin3(lin, "up_proj", h2q)
    mlp = gate * up
    mlpq = act_fakequant(mlp, qmax_act, ste)
    return x + _lin3(lin, "down_proj", mlpq)


# ---------------------------------------------------------------------------
# Block forwards (teacher / student)


def block_fp_fwd(x, n1, n2, weights: Dict[str, jax.Array], cfg: ModelConfig,
                 qmax_act):
    """FP teacher forward of one block (input/target collection)."""
    lin = lambda name, h: h @ weights[name].T
    return block_core(x, n1, n2, lin, cfg, qmax_act, ste=False)


def block_quant_fwd(x, n1, n2, qstate: Dict[str, tuple], cfg: ModelConfig,
                    qmax_w, qmax_act):
    """Quantized block forward through the Pallas fused kernel (L1).

    qstate[name] = (w_floor, s, z, nu, v). Used for reconstruction-loss
    probes (Fig. 4) and quantized-block validation; not differentiated.
    """
    def lin(name, h):
        wf, s, z, nu, v = qstate[name]
        return fused_qdq_matmul(h, wf, s, z, nu, v, qmax_w)

    def norm_fn(t3, w):
        b, t, d = t3.shape
        return rmsnorm_pallas(t3.reshape(b * t, d), w).reshape(b, t, d)

    return block_core(x, n1, n2, lin, cfg, qmax_act, ste=False,
                      norm_fn=norm_fn)


def _block_soft_fwd(x, n1, n2, qstate, nus, vs, cfg, qmax_w, qmax_act):
    """Differentiable student forward: materialize soft-qdq weights (jnp)."""
    whats = {}
    for i, name in enumerate(LINEAR_NAMES):
        wf, s, z = qstate[name]
        whats[name] = soft_qdq(wf, s, z, nus[i], vs[i], qmax_w)
    lin = lambda name, h: h @ whats[name].T
    return block_core(x, n1, n2, lin, cfg, qmax_act, ste=True)


# ---------------------------------------------------------------------------
# Adam helper


def _adam(p, g, m, u, lr, t, wd=0.0):
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    u = ADAM_B2 * u + (1.0 - ADAM_B2) * g * g
    mh = m / (1.0 - ADAM_B1 ** t)
    uh = u / (1.0 - ADAM_B2 ** t)
    p = p - lr * (mh / (jnp.sqrt(uh) + ADAM_EPS) + wd * p)
    return p, m, u


# ---------------------------------------------------------------------------
# TesseraQ PAR soften-phase step (the paper's Eq. 7 + DST Eq. 9)


def par_step(x, y, n1, n2, qstate, nus, vs, m_nu, u_nu, m_v, u_v,
             lr, t, qmax_w, qmax_act, cfg: ModelConfig):
    """One Adam step on (nu, v) against the block reconstruction MSE.

    Hardened variables arrive saturated at +-SAT_NU, so their sigmoid
    gradient is exactly zero — the paper's memory-efficient masking trick.
    Returns (loss, nus', vs', m_nu', u_nu', m_v', u_v').
    """

    def loss_fn(nus_, vs_):
        yh = _block_soft_fwd(x, n1, n2, qstate, nus_, vs_, cfg,
                             qmax_w, qmax_act)
        diff = yh - y
        return jnp.mean(diff * diff)

    loss, (g_nu, g_v) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
        tuple(nus), tuple(vs))
    new_nus, new_m_nu, new_u_nu = [], [], []
    new_vs, new_m_v, new_u_v = [], [], []
    for i in range(len(LINEAR_NAMES)):
        p, m, u = _adam(nus[i], g_nu[i], m_nu[i], u_nu[i], lr, t)
        new_nus.append(p); new_m_nu.append(m); new_u_nu.append(u)
        p, m, u = _adam(vs[i], g_v[i], m_v[i], u_v[i], lr, t,
                        wd=DST_WEIGHT_DECAY)
        new_vs.append(p); new_m_v.append(m); new_u_v.append(u)
    return loss, new_nus, new_vs, new_m_nu, new_u_nu, new_m_v, new_u_v


# ---------------------------------------------------------------------------
# OmniQuant-style learnable-weight-clipping step (baseline)


def lwc_step(x, y, n1, n2, weights, gammas, betas, m_g, u_g, m_b, u_b,
             lr, t, qmax_w, qmax_act, cfg: ModelConfig):
    """One Adam step on per-group clipping logits (STE through rounding)."""

    def loss_fn(gs, bs):
        whats = {}
        for i, name in enumerate(LINEAR_NAMES):
            whats[name] = lwc_qdq(weights[name], gs[i], bs[i], qmax_w)
        lin = lambda name, h: h @ whats[name].T
        yh = block_core(x, n1, n2, lin, cfg, qmax_act, ste=True)
        diff = yh - y
        return jnp.mean(diff * diff)

    loss, (g_g, g_b) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
        tuple(gammas), tuple(betas))
    ng_, nb_, nmg, nug, nmb, nub = [], [], [], [], [], []
    for i in range(len(LINEAR_NAMES)):
        p, m, u = _adam(gammas[i], g_g[i], m_g[i], u_g[i], lr, t)
        ng_.append(p); nmg.append(m); nug.append(u)
        p, m, u = _adam(betas[i], g_b[i], m_b[i], u_b[i], lr, t)
        nb_.append(p); nmb.append(m); nub.append(u)
    return loss, ng_, nb_, nmg, nug, nmb, nub


# ---------------------------------------------------------------------------
# Full model


def model_apply(tokens, params: Dict[str, jax.Array], cfg: ModelConfig,
                qmax_act):
    """Forward to final hidden states. tokens: [B, T] int32.

    Blocks run under lax.scan over the stacked [n_layers, ...] parameter
    tensors (smaller HLO, faster AOT compile, layout matches the Rust
    parameter store).
    """
    x = params["emb"][tokens]

    block_keys = LINEAR_NAMES + ["norm1", "norm2"]
    stacked = {k: params[k] for k in block_keys}

    def body(x, layer):
        lin = lambda name, h: h @ layer[name].T
        x = block_core(x, layer["norm1"], layer["norm2"], lin, cfg,
                       qmax_act, ste=False)
        return x, None

    x, _ = jax.lax.scan(body, x, stacked)
    return rmsnorm(x, params["norm_f"])


def model_nll(tokens, params, cfg: ModelConfig, qmax_act, head_t=None):
    """Per-position next-token NLL, [B, T-1] (PPL + likelihood ranking).

    head_t: optional [d, d] matrix applied between the final norm and the
    tied head. Identity for plain models; carries diag(norm_f) and the
    QuaRot rotation for transformed checkpoints (rust quant::rotate).
    """
    h = model_apply(tokens, params, cfg, qmax_act)
    if head_t is not None:
        h = h @ head_t
    logits = h @ params["emb"].T  # tied head (kept FP, as in the paper)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return nll


def train_step(tokens, params, m, u, lr, t, cfg: ModelConfig):
    """Full-model Adam pretraining step (E2E driver; FP activations)."""

    def loss_fn(p):
        return jnp.mean(model_nll(tokens, p, cfg, jnp.float32(65535.0)))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new_p, new_m, new_u = {}, {}, {}
    for k in params:
        new_p[k], new_m[k], new_u[k] = _adam(params[k], grads[k],
                                             m[k], u[k], lr, t)
    return loss, new_p, new_m, new_u
