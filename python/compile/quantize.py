"""Differentiable quantization math (L2, pure jnp).

These functions are the single source of truth for the quantization
semantics of the whole stack: the Pallas kernels (L1) are tested against
them, and the Rust host-side quantizer (rust/src/quant/) mirrors them
bit-for-bit (same clamp orders, same STE placement).

Shapes convention: a linear weight is W[out, in]; groups split the *input*
dimension, so per-group parameters are [out, n_groups] and a grouped view
of the weight is [out, n_groups, g].
"""

import jax
import jax.numpy as jnp

# |nu| >= SAT_NU means "hardened". At 100, f32 sigmoid saturates *exactly*
# (exp(100) == inf), so hardened logits receive exactly-zero gradients —
# the paper's memory-efficient alternative to masking.
SAT_NU = 100.0


def ste_round(x):
    """Round with a straight-through gradient (identity backward)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def grouped(w, g):
    """[out, in] -> [out, in//g, g] view of a weight."""
    o, i = w.shape
    return w.reshape(o, i // g, g)


def ungrouped(wg):
    o, ng, g = wg.shape
    return wg.reshape(o, ng * g)


def minmax_scale(w_grouped, gamma, beta, qmax):
    """Asymmetric scale/zero-point from clipped min/max (paper Eq. 1).

    gamma/beta are the clip factors on max/min, shape [out, n_groups]
    (broadcastable). Returns (s, z) with shape [out, n_groups].
    """
    mx = jnp.max(w_grouped, axis=-1)
    mn = jnp.min(w_grouped, axis=-1)
    s = (gamma * mx - beta * mn) / qmax
    s = jnp.maximum(s, 1e-9)
    z = jnp.round(-beta * mn / s)
    return s, z


def soft_qdq(w_floor, s, z, nu, v, qmax):
    """TesseraQ soft quant-dequant (paper Eq. 4 + Eq. 9).

    w_floor: [out, in]  precomputed floor(W/s) on the group grid (f32).
    s, z:    [out, n_groups] step size / zero point.
    nu:      [out, in]  soft rounding logits; hardened entries are +-40.
    v:       [out, n_groups] dequantization-scale-tuning logits.
    qmax:    scalar, 2^N - 1 (traced, so one artifact serves all widths).

    Returns the fake-quantized weight, [out, in].
    """
    o, i = w_floor.shape
    ng = s.shape[1]
    g = i // ng
    wf = w_floor.reshape(o, ng, g)
    alpha = jax.nn.sigmoid(nu).reshape(o, ng, g)
    q = jnp.clip(wf + alpha + z[..., None], 0.0, qmax)
    deq = 2.0 * jax.nn.sigmoid(v)[..., None] * s[..., None] * (q - z[..., None])
    return deq.reshape(o, i)


def hard_qdq(w_floor, s, z, nu, v, qmax):
    """Post-PAR hard quant-dequant: alpha = 1[nu > 0] (paper Eq. 5/8)."""
    o, i = w_floor.shape
    ng = s.shape[1]
    g = i // ng
    wf = w_floor.reshape(o, ng, g)
    alpha = (nu > 0.0).astype(w_floor.dtype).reshape(o, ng, g)
    q = jnp.clip(wf + alpha + z[..., None], 0.0, qmax)
    deq = 2.0 * jax.nn.sigmoid(v)[..., None] * s[..., None] * (q - z[..., None])
    return deq.reshape(o, i)


def rtn_qdq(w, s, z, qmax):
    """Plain round-to-nearest quant-dequant on a grouped grid."""
    o, i = w.shape
    ng = s.shape[1]
    g = i // ng
    wg = w.reshape(o, ng, g)
    q = jnp.clip(jnp.round(wg / s[..., None]) + z[..., None], 0.0, qmax)
    return (s[..., None] * (q - z[..., None])).reshape(o, i)


def lwc_qdq(w, gamma_raw, beta_raw, qmax):
    """OmniQuant-style learnable weight clipping with STE rounding.

    gamma_raw/beta_raw: [out, n_groups] logits; clip factors are
    sigmoid(raw) in (0, 1], exactly as OmniQuant's LWC parameterization.
    Differentiable w.r.t. gamma_raw/beta_raw through the STE.
    """
    o, i = w.shape
    ng = gamma_raw.shape[1]
    g = i // ng
    wg = w.reshape(o, ng, g)
    gamma = jax.nn.sigmoid(gamma_raw)
    beta = jax.nn.sigmoid(beta_raw)
    mx = jnp.max(wg, axis=-1)
    mn = jnp.min(wg, axis=-1)
    s = jnp.maximum((gamma * mx - beta * mn) / qmax, 1e-9)
    z = ste_round(-beta * mn / s)
    q = jnp.clip(ste_round(wg / s[..., None]) + z[..., None], 0.0, qmax)
    return (s[..., None] * (q - z[..., None])).reshape(o, i)


def act_fakequant(x, qmax, ste=False):
    """Per-token asymmetric activation fake-quant (paper's A4/A8 setup).

    x: [..., features]; one (s, z) per token (all leading dims).
    qmax >= 60000 is treated as the FP16/A16 passthrough sentinel so a
    single artifact serves A16/A8/A4/A3 via a runtime scalar.
    """
    rnd = ste_round if ste else jnp.round
    mx = jnp.max(x, axis=-1, keepdims=True)
    mn = jnp.min(x, axis=-1, keepdims=True)
    s = jnp.maximum((mx - mn) / qmax, 1e-8)
    z = rnd(-mn / s)
    q = jnp.clip(rnd(x / s) + z, 0.0, qmax)
    xq = s * (q - z)
    return jnp.where(qmax >= 60000.0, x, xq)


def nu_init(w, s, z, qmax):
    """Initialize rounding logits so soft_qdq(w) == rtn-floor(w) + frac == w.

    nu = sigmoid^-1(frac(W/s)) clipped away from {0,1} for finite logits.
    Mirrored by rust/src/coordinator/par.rs.
    """
    o, i = w.shape
    ng = s.shape[1]
    g = i // ng
    wg = w.reshape(o, ng, g)
    ratio = wg / s[..., None]
    frac = ratio - jnp.floor(ratio)
    frac = jnp.clip(frac, 1e-4, 1.0 - 1e-4)
    return jnp.log(frac / (1.0 - frac)).reshape(o, i)


def w_floor_init(w, s):
    """floor(W/s) on the group grid, [out, in] (f32)."""
    o, i = w.shape
    ng = s.shape[1]
    g = i // ng
    wg = w.reshape(o, ng, g)
    return jnp.floor(wg / s[..., None]).reshape(o, i)
