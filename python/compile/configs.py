"""Model and quantization configurations shared by the AOT compile path.

The Rust coordinator mirrors these configs (rust/src/model/config.rs); the
artifact manifest emitted by aot.py is the contract between the two sides,
but the *named presets* here must stay in sync with the Rust presets.

Sizes are chosen so that every paper group scheme divides every linear's
input dimension (g64 and g128 must divide d_model and d_ff).
"""

from dataclasses import dataclass, field
from typing import Dict, List


@dataclass(frozen=True)
class ModelConfig:
    """LLaMA-architecture decoder configuration.

    Linears per block follow the paper's Table 7 naming:
    q_proj/k_proj/v_proj/o_proj [d_model or d_kv, d_model],
    gate_proj/up_proj [d_ff, d_model], down_proj [d_model, d_ff].
    """

    name: str
    vocab_size: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    n_layers: int
    max_seq: int
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.head_dim

    def linear_shapes(self) -> Dict[str, tuple]:
        """(out, in) shape of every quantizable linear in one block."""
        d, dkv, f = self.d_model, self.d_kv, self.d_ff
        return {
            "q_proj": (d, d),
            "k_proj": (dkv, d),
            "v_proj": (dkv, d),
            "o_proj": (d, d),
            "gate_proj": (f, d),
            "up_proj": (f, d),
            "down_proj": (d, f),
        }

    def param_count(self) -> int:
        n = self.vocab_size * self.d_model + self.d_model  # emb + final norm
        for (o, i) in self.linear_shapes().values():
            n += o * i
        n += 2 * self.d_model  # two norms
        return self.vocab_size * self.d_model + self.d_model + self.n_layers * (
            sum(o * i for (o, i) in self.linear_shapes().values()) + 2 * self.d_model
        )


LINEAR_NAMES: List[str] = [
    "q_proj", "k_proj", "v_proj", "o_proj", "gate_proj", "up_proj", "down_proj",
]

MODELS: Dict[str, ModelConfig] = {
    # Unit-test scale: everything runs in milliseconds.
    "nano": ModelConfig("nano", vocab_size=128, d_model=64, n_heads=2,
                        n_kv_heads=2, d_ff=192, n_layers=2, max_seq=64),
    # Main experiment scale (analogue of LLaMA-2-7B in the tables).
    "tiny": ModelConfig("tiny", vocab_size=256, d_model=256, n_heads=4,
                        n_kv_heads=4, d_ff=768, n_layers=6, max_seq=128),
    # GQA variant (analogue of Mistral-7B, Table 11).
    "tiny-gqa": ModelConfig("tiny-gqa", vocab_size=256, d_model=256, n_heads=4,
                            n_kv_heads=2, d_ff=896, n_layers=6, max_seq=128),
    # Larger scale for the cross-size sweeps (analogue of 13B/70B rows).
    "small": ModelConfig("small", vocab_size=512, d_model=384, n_heads=6,
                         n_kv_heads=6, d_ff=1152, n_layers=8, max_seq=128),
}


def group_size_for(scheme: str, in_features: int) -> int:
    """Resolve a group scheme name to a concrete group size.

    "pc" is per-channel quantization: one group spanning the whole input
    dimension of each output channel. "gN" is per-group with size N.
    """
    if scheme == "pc":
        return in_features
    if scheme.startswith("g"):
        g = int(scheme[1:])
        if in_features % g != 0:
            raise ValueError(f"group size {g} does not divide {in_features}")
        return g
    raise ValueError(f"unknown group scheme {scheme!r}")


# Group schemes built per model size by aot.py.
SCHEMES: Dict[str, List[str]] = {
    "nano": ["pc", "g32"],
    "tiny": ["pc", "g64", "g128"],
    "tiny-gqa": ["pc", "g64", "g128"],
    "small": ["pc", "g64", "g128"],
}

# Calibration batch size baked into the block-step artifacts (Table 5's
# batch-size sweep rebuilds with --batch).
DEFAULT_CALIB_BATCH = 4
# Pretraining batch size baked into model_train_step.
DEFAULT_TRAIN_BATCH = 8
