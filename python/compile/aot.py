"""AOT artifact emitter: lower every L2 graph to HLO *text* + manifest.

Run once at build time (`make artifacts`); the Rust runtime
(rust/src/runtime/) loads `artifacts/<name>.hlo.txt` via
HloModuleProto::from_text_file and compiles it on the PJRT CPU client.

HLO text — NOT serialized protos — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids (see
/opt/xla-example/README.md).

`artifacts/manifest.json` is the machine-readable contract: for every
artifact it records the positional input/output names, shapes and dtypes,
plus model/quant metadata. rust/src/runtime/manifest.rs parses it.
"""

import argparse
import json
import os
import sys
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import (DEFAULT_CALIB_BATCH, DEFAULT_TRAIN_BATCH, LINEAR_NAMES,
                      MODELS, SCHEMES, ModelConfig, group_size_for)
from . import model as M
from .kernels.qmatmul import qmatmul
from .quantize import SAT_NU

F32 = jnp.float32
I32 = jnp.int32

EVAL_BATCH = 8  # sequences per model_fwd_nll call


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants is MANDATORY: the default printer elides big
    # array constants as literally "{...}", which the XLA 0.5.1 text
    # parser silently turns into zeros — rope tables and causal masks
    # (embedded as constants by jnp.arange/jnp.tril) get corrupted and
    # the artifact diverges from jax by ~1e-2. Found the hard way; see
    # DESIGN.md §AOT-gotchas.
    return comp.as_hlo_text(print_large_constants=True)


def group_shapes(cfg: ModelConfig, scheme: str) -> Dict[str, Tuple[int, int]]:
    """[out, n_groups] per linear for a group scheme."""
    out = {}
    for name, (o, i) in cfg.linear_shapes().items():
        g = group_size_for(scheme, i)
        out[name] = (o, i // g)
    return out


# ---------------------------------------------------------------------------
# Artifact builders: each returns (fn, input_specs, input_names, output_names)


def build_model_train_step(cfg: ModelConfig):
    shapes = M.param_shapes(cfg)
    names = (["tokens"]
             + [f"param.{n}" for n in M.PARAM_NAMES]
             + [f"m.{n}" for n in M.PARAM_NAMES]
             + [f"u.{n}" for n in M.PARAM_NAMES]
             + ["lr", "t"])
    specs = ([spec((DEFAULT_TRAIN_BATCH, cfg.max_seq), I32)]
             + [spec(shapes[n]) for n in M.PARAM_NAMES] * 3
             + [spec(()), spec(())])

    def fn(*args):
        i = 0
        tokens = args[i]; i += 1
        p = {n: args[i + j] for j, n in enumerate(M.PARAM_NAMES)}; i += len(M.PARAM_NAMES)
        m = {n: args[i + j] for j, n in enumerate(M.PARAM_NAMES)}; i += len(M.PARAM_NAMES)
        u = {n: args[i + j] for j, n in enumerate(M.PARAM_NAMES)}; i += len(M.PARAM_NAMES)
        lr, t = args[i], args[i + 1]
        loss, np_, nm, nu_ = M.train_step(tokens, p, m, u, lr, t, cfg)
        outs = [loss]
        outs += [np_[n] for n in M.PARAM_NAMES]
        outs += [nm[n] for n in M.PARAM_NAMES]
        outs += [nu_[n] for n in M.PARAM_NAMES]
        return tuple(outs)

    out_names = (["loss"]
                 + [f"param.{n}" for n in M.PARAM_NAMES]
                 + [f"m.{n}" for n in M.PARAM_NAMES]
                 + [f"u.{n}" for n in M.PARAM_NAMES])
    return fn, specs, names, out_names


def build_model_fwd_nll(cfg: ModelConfig):
    shapes = M.param_shapes(cfg)
    names = (["tokens"] + [f"param.{n}" for n in M.PARAM_NAMES]
             + ["head_t", "qmax_act"])
    specs = ([spec((EVAL_BATCH, cfg.max_seq), I32)]
             + [spec(shapes[n]) for n in M.PARAM_NAMES]
             + [spec((cfg.d_model, cfg.d_model)), spec(())])

    def fn(*args):
        tokens = args[0]
        p = {n: args[1 + j] for j, n in enumerate(M.PARAM_NAMES)}
        head_t = args[1 + len(M.PARAM_NAMES)]
        qmax_act = args[2 + len(M.PARAM_NAMES)]
        return (M.model_nll(tokens, p, cfg, qmax_act, head_t),)

    return fn, specs, names, ["nll"]


def build_block_fp_fwd(cfg: ModelConfig, batch: int):
    lsh = cfg.linear_shapes()
    names = (["x", "norm1", "norm2"]
             + [f"w.{n}" for n in LINEAR_NAMES] + ["qmax_act"])
    specs = ([spec((batch, cfg.max_seq, cfg.d_model)),
              spec((cfg.d_model,)), spec((cfg.d_model,))]
             + [spec(lsh[n]) for n in LINEAR_NAMES] + [spec(())])

    def fn(*args):
        x, n1, n2 = args[0], args[1], args[2]
        w = {n: args[3 + j] for j, n in enumerate(LINEAR_NAMES)}
        qa = args[3 + len(LINEAR_NAMES)]
        return (M.block_fp_fwd(x, n1, n2, w, cfg, qa),)

    return fn, specs, names, ["y"]


def build_block_quant_fwd(cfg: ModelConfig, scheme: str, batch: int):
    lsh = cfg.linear_shapes()
    gsh = group_shapes(cfg, scheme)
    names = ["x", "norm1", "norm2"]
    specs = [spec((batch, cfg.max_seq, cfg.d_model)),
             spec((cfg.d_model,)), spec((cfg.d_model,))]
    for n in LINEAR_NAMES:
        names += [f"wf.{n}", f"s.{n}", f"z.{n}", f"nu.{n}", f"v.{n}"]
        specs += [spec(lsh[n]), spec(gsh[n]), spec(gsh[n]),
                  spec(lsh[n]), spec(gsh[n])]
    names += ["qmax_w", "qmax_act"]
    specs += [spec(()), spec(())]

    def fn(*args):
        x, n1, n2 = args[0], args[1], args[2]
        i = 3
        qstate = {}
        for n in LINEAR_NAMES:
            qstate[n] = tuple(args[i:i + 5]); i += 5
        qmax_w, qmax_act = args[i], args[i + 1]
        return (M.block_quant_fwd(x, n1, n2, qstate, cfg, qmax_w, qmax_act),)

    return fn, specs, names, ["y"]


def build_block_par_step(cfg: ModelConfig, scheme: str, batch: int):
    lsh = cfg.linear_shapes()
    gsh = group_shapes(cfg, scheme)
    nL = len(LINEAR_NAMES)
    names = ["x", "y", "norm1", "norm2"]
    specs = [spec((batch, cfg.max_seq, cfg.d_model))] * 2 + \
            [spec((cfg.d_model,))] * 2
    for n in LINEAR_NAMES:
        names += [f"wf.{n}", f"s.{n}", f"z.{n}"]
        specs += [spec(lsh[n]), spec(gsh[n]), spec(gsh[n])]
    for group, shfn in [("nu", lambda n: lsh[n]), ("v", lambda n: gsh[n]),
                        ("m_nu", lambda n: lsh[n]), ("u_nu", lambda n: lsh[n]),
                        ("m_v", lambda n: gsh[n]), ("u_v", lambda n: gsh[n])]:
        for n in LINEAR_NAMES:
            names.append(f"{group}.{n}")
            specs.append(spec(shfn(n)))
    names += ["lr", "t", "qmax_w", "qmax_act"]
    specs += [spec(())] * 4

    def fn(*args):
        x, y, n1, n2 = args[:4]
        i = 4
        qstate = {}
        for n in LINEAR_NAMES:
            qstate[n] = tuple(args[i:i + 3]); i += 3
        nus = list(args[i:i + nL]); i += nL
        vs = list(args[i:i + nL]); i += nL
        m_nu = list(args[i:i + nL]); i += nL
        u_nu = list(args[i:i + nL]); i += nL
        m_v = list(args[i:i + nL]); i += nL
        u_v = list(args[i:i + nL]); i += nL
        lr, t, qmax_w, qmax_act = args[i:i + 4]
        loss, nnu, nv, nmn, nun, nmv, nuv = M.par_step(
            x, y, n1, n2, qstate, nus, vs, m_nu, u_nu, m_v, u_v,
            lr, t, qmax_w, qmax_act, cfg)
        return tuple([loss] + nnu + nv + nmn + nun + nmv + nuv)

    out_names = ["loss"]
    for group in ["nu", "v", "m_nu", "u_nu", "m_v", "u_v"]:
        out_names += [f"{group}.{n}" for n in LINEAR_NAMES]
    return fn, specs, names, out_names


def build_block_lwc_step(cfg: ModelConfig, scheme: str, batch: int):
    lsh = cfg.linear_shapes()
    gsh = group_shapes(cfg, scheme)
    nL = len(LINEAR_NAMES)
    names = ["x", "y", "norm1", "norm2"]
    specs = [spec((batch, cfg.max_seq, cfg.d_model))] * 2 + \
            [spec((cfg.d_model,))] * 2
    names += [f"w.{n}" for n in LINEAR_NAMES]
    specs += [spec(lsh[n]) for n in LINEAR_NAMES]
    for group in ["gamma", "beta", "m_g", "u_g", "m_b", "u_b"]:
        for n in LINEAR_NAMES:
            names.append(f"{group}.{n}")
            specs.append(spec(gsh[n]))
    names += ["lr", "t", "qmax_w", "qmax_act"]
    specs += [spec(())] * 4

    def fn(*args):
        x, y, n1, n2 = args[:4]
        i = 4
        w = {n: args[i + j] for j, n in enumerate(LINEAR_NAMES)}; i += nL
        gam = list(args[i:i + nL]); i += nL
        bet = list(args[i:i + nL]); i += nL
        m_g = list(args[i:i + nL]); i += nL
        u_g = list(args[i:i + nL]); i += nL
        m_b = list(args[i:i + nL]); i += nL
        u_b = list(args[i:i + nL]); i += nL
        lr, t, qmax_w, qmax_act = args[i:i + 4]
        loss, ng, nb, nmg, nug, nmb, nub = M.lwc_step(
            x, y, n1, n2, w, gam, bet, m_g, u_g, m_b, u_b,
            lr, t, qmax_w, qmax_act, cfg)
        return tuple([loss] + ng + nb + nmg + nug + nmb + nub)

    out_names = ["loss"]
    for group in ["gamma", "beta", "m_g", "u_g", "m_b", "u_b"]:
        out_names += [f"{group}.{n}" for n in LINEAR_NAMES]
    return fn, specs, names, out_names


def build_qmatmul(cfg: ModelConfig, bits: int):
    """Standalone packed dequant-matmul kernel artifact (decode shapes)."""
    k = cfg.d_model
    o = cfg.d_model
    g = 64 if k % 64 == 0 else k
    per_word = 32 // bits
    nw = (k + per_word - 1) // per_word
    m = cfg.max_seq
    names = ["x", "packed", "s", "z"]
    specs = [spec((m, k)), spec((o, nw), I32),
             spec((o, k // g)), spec((o, k // g))]

    def fn(x, packed, s, z):
        return (qmatmul(x, packed, s, z, bits),)

    return fn, specs, names, ["y"]


# ---------------------------------------------------------------------------
# Driver


def emit(out_dir: str, name: str, builder, manifest: list, meta: dict,
         force: bool) -> None:
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    fn, specs, in_names, out_names = builder
    entry = {
        "name": name,
        "path": os.path.basename(path),
        "inputs": [{"name": n, "shape": list(s.shape),
                    "dtype": str(s.dtype.name)} for n, s in zip(in_names, specs)],
        "outputs": out_names,
        "meta": meta,
    }
    manifest.append(entry)
    if os.path.exists(path) and not force:
        print(f"  [cached] {name}")
        return
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  [lowered] {name} ({len(text)} chars, {len(specs)} inputs)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--sizes", default="nano,tiny,tiny-gqa,small")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    sizes = [s for s in args.sizes.split(",") if s]

    manifest: list = []
    for size in sizes:
        cfg = MODELS[size]
        print(f"== {size}: {cfg.param_count()/1e6:.2f}M params ==")
        mmeta = {"size": size, "model": cfg.__dict__,
                 "train_batch": DEFAULT_TRAIN_BATCH,
                 "eval_batch": EVAL_BATCH,
                 "calib_batch": DEFAULT_CALIB_BATCH,
                 "sat_nu": SAT_NU}
        emit(out_dir, f"model_train_step.{size}",
             build_model_train_step(cfg), manifest,
             {**mmeta, "kind": "model_train_step"}, args.force)
        emit(out_dir, f"model_fwd_nll.{size}",
             build_model_fwd_nll(cfg), manifest,
             {**mmeta, "kind": "model_fwd_nll"}, args.force)
        emit(out_dir, f"block_fp_fwd.{size}",
             build_block_fp_fwd(cfg, DEFAULT_CALIB_BATCH), manifest,
             {**mmeta, "kind": "block_fp_fwd", "batch": DEFAULT_CALIB_BATCH},
             args.force)
        schemes = SCHEMES[size] if size != "tiny-gqa" else ["g128"]
        for scheme in schemes:
            smeta = {**mmeta, "scheme": scheme}
            emit(out_dir, f"block_quant_fwd.{size}.{scheme}",
                 build_block_quant_fwd(cfg, scheme, DEFAULT_CALIB_BATCH),
                 manifest, {**smeta, "kind": "block_quant_fwd",
                            "batch": DEFAULT_CALIB_BATCH}, args.force)
            emit(out_dir, f"block_par_step.{size}.{scheme}",
                 build_block_par_step(cfg, scheme, DEFAULT_CALIB_BATCH),
                 manifest, {**smeta, "kind": "block_par_step",
                            "batch": DEFAULT_CALIB_BATCH}, args.force)
            emit(out_dir, f"block_lwc_step.{size}.{scheme}",
                 build_block_lwc_step(cfg, scheme, DEFAULT_CALIB_BATCH),
                 manifest, {**smeta, "kind": "block_lwc_step",
                            "batch": DEFAULT_CALIB_BATCH}, args.force)
        # Table 5 batch-size sweep artifacts (tiny, g128 only).
        if size == "tiny":
            for b in (1, 2):
                emit(out_dir, f"block_par_step.{size}.g128.b{b}",
                     build_block_par_step(cfg, "g128", b), manifest,
                     {**mmeta, "scheme": "g128", "kind": "block_par_step",
                      "batch": b}, args.force)
        # Packed dequant-matmul kernel artifacts (L1 bench/test).
        if size in ("nano", "tiny"):
            for bits in (2, 3, 4):
                emit(out_dir, f"qmatmul_w{bits}.{size}",
                     build_qmatmul(cfg, bits), manifest,
                     {**mmeta, "kind": "qmatmul", "bits": bits,
                      "group": 64 if cfg.d_model % 64 == 0 else cfg.d_model},
                     args.force)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump({"artifacts": manifest,
                   "param_names": M.PARAM_NAMES,
                   "linear_names": LINEAR_NAMES}, f, indent=1)
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
