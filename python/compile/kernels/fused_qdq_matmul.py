"""L1 Pallas kernel: fused soft quant-dequant + matmul.

This is the block-forward hot-spot of TesseraQ: every linear in a decoder
block evaluates  y = x @ soft_qdq(W).T  thousands of times during PAR.

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's CUDA analogue
would stage W tiles in shared memory per threadblock; here each grid step
owns a VMEM-resident (bo x K) weight tile plus its rounding state, rebuilds
the dequantized tile once, and feeds an (bm x K)·(K x bo) MXU contraction.
The grid is (M/bm, O/bo); K (<= d_ff <= 1152) stays unsplit so the group
structure [out, n_groups, g] never straddles a tile boundary.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so this lowers to plain HLO (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (keeps BlockSpecs exact)."""
    t = min(n, cap)
    while n % t != 0:
        t -= 1
    return t


def _kernel(x_ref, wf_ref, s_ref, z_ref, nu_ref, v_ref, qmax_ref, o_ref):
    x = x_ref[...]                    # [bm, K]
    wf = wf_ref[...]                  # [bo, K]
    s = s_ref[...]                    # [bo, G]
    z = z_ref[...]                    # [bo, G]
    nu = nu_ref[...]                  # [bo, K]
    v = v_ref[...]                    # [bo, G]
    qmax = qmax_ref[0, 0]
    bo, k = wf.shape
    ng = s.shape[1]
    g = k // ng
    alpha = jax.nn.sigmoid(nu).reshape(bo, ng, g)
    q = jnp.clip(wf.reshape(bo, ng, g) + alpha + z[..., None], 0.0, qmax)
    deq = 2.0 * jax.nn.sigmoid(v)[..., None] * s[..., None] * (q - z[..., None])
    what = deq.reshape(bo, k)
    o_ref[...] = jnp.dot(x, what.T, preferred_element_type=jnp.float32)


def fused_qdq_matmul(x, w_floor, s, z, nu, v, qmax, bm=128, bo=128):
    """y = x @ soft_qdq(w_floor, s, z, nu, v, qmax).T via Pallas.

    x: [M, K]; w_floor/nu: [O, K]; s/z/v: [O, G]; qmax: scalar-like.
    """
    m, k = x.shape
    o = w_floor.shape[0]
    ng = s.shape[1]
    bm = _tile(m, bm)
    bo = _tile(o, bo)
    qmax_arr = jnp.asarray(qmax, jnp.float32).reshape(1, 1)
    grid = (m // bm, o // bo)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bo, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bo, ng), lambda i, j: (j, 0)),
            pl.BlockSpec((bo, ng), lambda i, j: (j, 0)),
            pl.BlockSpec((bo, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bo, ng), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bo), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, o), jnp.float32),
        interpret=True,
    )(x, w_floor, s, z, nu, v, qmax_arr)
