"""Pure-jnp oracles for every Pallas kernel (the L1 correctness contract).

pytest (python/tests/test_kernels.py) asserts kernel == oracle across a
hypothesis sweep of shapes/dtypes; the Rust host quantizer is additionally
tied to these semantics through the artifact integration tests.
"""

import jax
import jax.numpy as jnp

from ..quantize import soft_qdq


def rmsnorm_ref(x, w, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def qdq_matmul_ref(x, w_floor, s, z, nu, v, qmax):
    """y = x @ soft_qdq(W).T — the block-forward hot-spot."""
    what = soft_qdq(w_floor, s, z, nu, v, qmax)
    return x @ what.T


def unpack_codes_ref(packed, bits, k):
    """Unpack int32 words -> integer codes [out, k].

    Packing layout (mirrored by rust/src/quant/pack.rs): codes along the
    input dim, `per_word = 32 // bits` codes per word, code j occupies bits
    [bits*j, bits*(j+1)) of its word, low bits first. For bits=3 this
    packs 10 codes per word and wastes the top 2 bits.
    """
    per_word = 32 // bits
    mask = (1 << bits) - 1
    shifts = jnp.arange(per_word, dtype=jnp.int32) * bits
    # [out, n_words, per_word]
    codes = (packed[..., None] >> shifts[None, None, :]) & mask
    o = packed.shape[0]
    return codes.reshape(o, per_word * packed.shape[1])[:, :k]


def qmatmul_ref(x, packed, s, z, bits):
    """y = x @ (s * (codes - z)).T with packed INT{2,3,4} weights."""
    k = x.shape[-1]
    codes = unpack_codes_ref(packed, bits, k).astype(jnp.float32)
    o = codes.shape[0]
    ng = s.shape[1]
    g = k // ng
    cg = codes.reshape(o, ng, g)
    w = (s[..., None] * (cg - z[..., None])).reshape(o, k)
    return x @ w.T
