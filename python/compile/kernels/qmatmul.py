"""L1 Pallas kernel: packed INT{2,3,4} dequant-matmul (serving hot-spot).

The paper's Table 8 measures Triton (INT2) and Exllama (INT4) GPU kernels;
this is the TPU-semantics restatement: weights live packed in HBM (int32
words, `32 // bits` codes per word, low bits first — layout shared with
rust/src/quant/pack.rs), each grid step unpacks one (bo x K) tile into
VMEM, dequantizes against per-group (s, z), and runs the MXU contraction.
Unpacking is a shift/mask broadcast (VPU-friendly), not a per-element loop.

bits is a *compile-time* constant (the packed layout depends on it), so
aot.py emits one artifact per bit-width: qmatmul_w{2,3,4}.<size>.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_qdq_matmul import _tile


def _make_kernel(bits: int, k: int):
    per_word = 32 // bits
    mask = (1 << bits) - 1

    def kernel(x_ref, p_ref, s_ref, z_ref, o_ref):
        x = x_ref[...]                  # [bm, K]
        packed = p_ref[...]             # [bo, n_words]
        s = s_ref[...]                  # [bo, G]
        z = z_ref[...]                  # [bo, G]
        bo = packed.shape[0]
        # iota instead of a captured arange: pallas kernels may not close
        # over device constants.
        shifts = jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, per_word), 2) * bits
        codes = (packed[..., None] >> shifts) & mask
        codes = codes.reshape(bo, per_word * packed.shape[1])[:, :k]
        ng = s.shape[1]
        g = k // ng
        cg = codes.astype(jnp.float32).reshape(bo, ng, g)
        w = (s[..., None] * (cg - z[..., None])).reshape(bo, k)
        o_ref[...] = jnp.dot(x, w.T, preferred_element_type=jnp.float32)

    return kernel


def qmatmul(x, packed, s, z, bits, bm=128, bo=128):
    """y = x @ dequant(packed, s, z).T with INT`bits` packed weights.

    x: [M, K] f32; packed: [O, ceil(K/per_word)] int32; s/z: [O, G].
    """
    m, k = x.shape
    o = packed.shape[0]
    ng = s.shape[1]
    nw = packed.shape[1]
    bm = _tile(m, bm)
    bo = _tile(o, bo)
    grid = (m // bm, o // bo)
    return pl.pallas_call(
        _make_kernel(bits, k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bo, nw), lambda i, j: (j, 0)),
            pl.BlockSpec((bo, ng), lambda i, j: (j, 0)),
            pl.BlockSpec((bo, ng), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bo), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, o), jnp.float32),
        interpret=True,
    )(x, packed, s, z)
