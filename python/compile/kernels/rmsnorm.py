"""L1 Pallas kernel: fused RMSNorm (row-tiled, weight broadcast in VMEM)."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_qdq_matmul import _tile


def _kernel(eps, x_ref, w_ref, o_ref):
    x = x_ref[...]
    w = w_ref[...]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(var + eps) * w


def rmsnorm(x2d, w, eps=1e-5, br=128):
    """RMSNorm over the last dim of x2d [rows, d]; w: [d]."""
    rows, d = x2d.shape
    br = _tile(rows, br)
    import functools
    return pl.pallas_call(
        functools.partial(_kernel, eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), jnp.float32),
        interpret=True,
    )(x2d, w.reshape(1, d))
