"""Artifact manifest consistency checks (the Python<->Rust contract)."""

import json
import os

import pytest

from compile.configs import LINEAR_NAMES, MODELS
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST),
    reason="run `make artifacts` first")


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_lists_existing_files(manifest):
    for a in manifest["artifacts"]:
        path = os.path.join(ART, a["path"])
        assert os.path.exists(path), a["name"]
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, a["name"]


def test_param_order_contract(manifest):
    assert manifest["param_names"] == M.PARAM_NAMES
    assert manifest["linear_names"] == LINEAR_NAMES


def test_par_step_io_symmetry(manifest):
    """Every PAR-step state input has a matching output (buffer cycling)."""
    for a in manifest["artifacts"]:
        if a["meta"]["kind"] != "block_par_step":
            continue
        in_names = {i["name"] for i in a["inputs"]}
        for out in a["outputs"]:
            if out == "loss":
                continue
            assert out in in_names, (a["name"], out)


def test_shapes_match_configs(manifest):
    for a in manifest["artifacts"]:
        meta = a["meta"]
        cfg = MODELS[meta["size"]]
        byname = {i["name"]: i for i in a["inputs"]}
        if meta["kind"] == "model_fwd_nll":
            assert byname["param.emb"]["shape"] == [cfg.vocab_size, cfg.d_model]
            assert byname["param.q_proj"]["shape"] == [
                cfg.n_layers, cfg.d_model, cfg.d_model]
            assert byname["tokens"]["dtype"] == "int32"
        if meta["kind"] == "block_par_step":
            x = byname["x"]
            assert x["shape"] == [meta["batch"], cfg.max_seq, cfg.d_model]
            # group shapes divide linear shapes
            for n in LINEAR_NAMES:
                o, i = cfg.linear_shapes()[n]
                so, sg = byname[f"s.{n}"]["shape"]
                assert so == o and i % sg == 0


def test_every_size_has_core_artifacts(manifest):
    kinds = {}
    for a in manifest["artifacts"]:
        kinds.setdefault(a["meta"]["size"], set()).add(a["meta"]["kind"])
    for size in ("nano", "tiny"):
        assert {"model_train_step", "model_fwd_nll", "block_fp_fwd",
                "block_par_step", "block_quant_fwd",
                "block_lwc_step"} <= kinds[size]
