"""L1 Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps the kernels across shapes/group sizes/bit-widths; this
is the primary correctness signal for the block-forward and serving hot
paths (DESIGN.md §3, L1).
"""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import quantize as Q
from compile.kernels import ref
from compile.kernels.fused_qdq_matmul import fused_qdq_matmul, _tile
from compile.kernels.qmatmul import qmatmul
from compile.kernels.rmsnorm import rmsnorm

SET = dict(max_examples=25, deadline=None)


def test_tile_divides():
    for n in (1, 2, 7, 24, 128, 768):
        for cap in (1, 8, 128):
            t = _tile(n, cap)
            assert n % t == 0 and t <= max(cap, 1)


@st.composite
def qdq_case(draw):
    g = draw(st.sampled_from([8, 16, 32]))
    ng = draw(st.integers(1, 4))
    k = g * ng
    m = draw(st.integers(1, 48))
    o = draw(st.integers(1, 48))
    bits = draw(st.sampled_from([2, 3, 4, 8]))
    seed = draw(st.integers(0, 2 ** 16))
    return m, k, o, g, bits, seed


@given(qdq_case())
@settings(**SET)
def test_fused_qdq_matmul_matches_ref(case):
    m, k, o, g, bits, seed = case
    rng = np.random.default_rng(seed)
    qmax = float(2 ** bits - 1)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(o, k)).astype(np.float32))
    ng = k // g
    s, z = Q.minmax_scale(w.reshape(o, ng, g), 1.0, 1.0, qmax)
    wf = Q.w_floor_init(w, s)
    nu = Q.nu_init(w, s, z, qmax)
    v = jnp.asarray(rng.normal(scale=0.1, size=(o, ng)).astype(np.float32))
    got = fused_qdq_matmul(x, wf, s, z, nu, v, qmax)
    want = ref.qdq_matmul_ref(x, wf, s, z, nu, v, qmax)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@st.composite
def pack_case(draw):
    bits = draw(st.sampled_from([2, 3, 4]))
    g = draw(st.sampled_from([16, 32, 64]))
    ng = draw(st.integers(1, 3))
    k = g * ng
    m = draw(st.integers(1, 32))
    o = draw(st.integers(1, 64))
    seed = draw(st.integers(0, 2 ** 16))
    return m, k, o, g, bits, seed


def pack_np(codes, bits):
    """Host packer mirroring rust/src/quant/pack.rs (low bits first)."""
    o, k = codes.shape
    per = 32 // bits
    nw = (k + per - 1) // per
    packed = np.zeros((o, nw), np.int64)
    for j in range(k):
        packed[:, j // per] |= codes[:, j].astype(np.int64) << (bits * (j % per))
    # reinterpret as int32 (values may have bit 31 set for bits=2)
    return packed.astype(np.uint32).view(np.int32).astype(np.int32)


@given(pack_case())
@settings(**SET)
def test_qmatmul_matches_ref(case):
    m, k, o, g, bits, seed = case
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 2 ** bits, size=(o, k))
    packed = jnp.asarray(pack_np(codes, bits))
    ng = k // g
    s = jnp.asarray(rng.uniform(0.01, 0.4, size=(o, ng)).astype(np.float32))
    z = jnp.asarray(
        rng.integers(0, 2 ** bits, size=(o, ng)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    got = qmatmul(x, packed, s, z, bits)
    want = ref.qmatmul_ref(x, packed, s, z, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@given(pack_case())
@settings(**SET)
def test_unpack_inverts_pack(case):
    _, k, o, _, bits, seed = case
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 2 ** bits, size=(o, k))
    packed = jnp.asarray(pack_np(codes, bits))
    got = ref.unpack_codes_ref(packed, bits, k)
    np.testing.assert_array_equal(np.asarray(got), codes)


@given(st.integers(1, 64), st.sampled_from([16, 64, 256]),
       st.integers(0, 2 ** 16))
@settings(**SET)
def test_rmsnorm_matches_ref(rows, d, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(rows, d)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    got = rmsnorm(x, w)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_qmatmul_exact_vs_dense_dequant():
    """Packed kernel == dense matmul against explicitly dequantized W."""
    rng = np.random.default_rng(7)
    o, k, g, bits = 48, 64, 16, 4
    codes = rng.integers(0, 16, size=(o, k))
    packed = jnp.asarray(pack_np(codes, bits))
    ng = k // g
    s = jnp.asarray(rng.uniform(0.01, 0.4, size=(o, ng)).astype(np.float32))
    z = jnp.asarray(rng.integers(0, 16, size=(o, ng)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(8, k)).astype(np.float32))
    w = (np.repeat(np.asarray(s), g, axis=1)
         * (codes - np.repeat(np.asarray(z), g, axis=1))).astype(np.float32)
    want = np.asarray(x) @ w.T
    got = np.asarray(qmatmul(x, packed, s, z, bits))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)
