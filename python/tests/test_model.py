"""L2 model graph tests: shapes, causality, convergence of the PAR/LWC
steps, and agreement between the Pallas block forward and the jnp path.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import quantize as Q
from compile.configs import LINEAR_NAMES, MODELS

CFG = MODELS["nano"]
A16 = jnp.float32(65535.0)


def init_params(cfg, seed=0):
    rng = np.random.default_rng(seed)
    shapes = M.param_shapes(cfg)
    p = {}
    for n, sh in shapes.items():
        if n.startswith("norm"):
            p[n] = jnp.ones(sh, jnp.float32)
        else:
            scale = 0.4 / np.sqrt(sh[-1])
            p[n] = jnp.asarray(rng.normal(scale=scale, size=sh),
                               jnp.float32)
    return p


def block_slice(params, layer):
    w = {n: params[n][layer] for n in LINEAR_NAMES}
    return w, params["norm1"][layer], params["norm2"][layer]


def mk_qstate(w, g, qmax, seed=1):
    rng = np.random.default_rng(seed)
    state = {}
    nus, vs = [], []
    for name in LINEAR_NAMES:
        o, i = w[name].shape
        gg = min(g, i)
        if i % gg:
            gg = i
        wg = w[name].reshape(o, i // gg, gg)
        s, z = Q.minmax_scale(wg, 1.0, 1.0, qmax)
        wf = Q.w_floor_init(w[name], s)
        state[name] = (wf, s, z)
        nus.append(Q.nu_init(w[name], s, z, qmax))
        vs.append(jnp.zeros_like(s))
    return state, nus, vs


def test_model_nll_shape_and_finite():
    p = init_params(CFG)
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, CFG.vocab_size, size=(2, CFG.max_seq)),
        jnp.int32)
    nll = M.model_nll(tokens, p, CFG, A16)
    assert nll.shape == (2, CFG.max_seq - 1)
    assert bool(jnp.all(jnp.isfinite(nll)))
    # untrained model ~ uniform: NLL close to log(V)
    assert abs(float(jnp.mean(nll)) - np.log(CFG.vocab_size)) < 1.0


def test_model_causality():
    """Changing a future token must not affect earlier NLL entries."""
    p = init_params(CFG)
    rng = np.random.default_rng(0)
    t1 = rng.integers(0, CFG.vocab_size, size=(1, CFG.max_seq))
    t2 = t1.copy()
    t2[0, -1] = (t2[0, -1] + 1) % CFG.vocab_size
    n1 = M.model_nll(jnp.asarray(t1, jnp.int32), p, CFG, A16)
    n2 = M.model_nll(jnp.asarray(t2, jnp.int32), p, CFG, A16)
    np.testing.assert_allclose(np.asarray(n1[0, :-1]), np.asarray(n2[0, :-1]),
                               rtol=1e-5, atol=1e-6)
    assert abs(float(n1[0, -1] - n2[0, -1])) > 1e-6


def test_gqa_variant_runs():
    cfg = MODELS["tiny-gqa"]
    # shrink for test speed: emulate by running one block only
    p = init_params(cfg)
    w, n1, n2 = block_slice(p, 0)
    x = jnp.asarray(np.random.default_rng(1).normal(
        size=(1, cfg.max_seq, cfg.d_model)), jnp.float32)
    y = M.block_fp_fwd(x, n1, n2, w, cfg, A16)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_block_quant_fwd_matches_soft_fwd():
    """Pallas block forward == differentiable jnp block forward."""
    p = init_params(CFG)
    w, n1, n2 = block_slice(p, 0)
    qmax = jnp.float32(15.0)
    state, nus, vs = mk_qstate(w, 32, 15.0)
    x = jnp.asarray(np.random.default_rng(2).normal(
        size=(4, CFG.max_seq, CFG.d_model)), jnp.float32)
    qstate5 = {n: state[n] + (nus[i], vs[i])
               for i, n in enumerate(LINEAR_NAMES)}
    got = M.block_quant_fwd(x, n1, n2, qstate5, CFG, qmax, A16)
    want = M._block_soft_fwd(x, n1, n2, state, nus, vs, CFG, qmax, A16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_par_step_reduces_reconstruction_loss():
    """A few PAR Adam steps must reduce the block reconstruction MSE."""
    p = init_params(CFG)
    w, n1, n2 = block_slice(p, 0)
    qmax = jnp.float32(3.0)  # 2-bit: large initial error
    state, nus, vs = mk_qstate(w, 32, 3.0)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(4, CFG.max_seq, CFG.d_model)),
                    jnp.float32)
    y = M.block_fp_fwd(x, n1, n2, w, CFG, A16)

    # RTN-equivalent starting point: saturate nu at the rounded value
    nus = [jnp.where(jax.nn.sigmoid(nu) > 0.5, 2.0, -2.0) for nu in nus]
    zeros = lambda ls: [jnp.zeros_like(a) for a in ls]
    m_nu, u_nu, m_v, u_v = zeros(nus), zeros(nus), zeros(vs), zeros(vs)
    step = jax.jit(lambda *a: M.par_step(*a, cfg=CFG))
    losses = []
    for t in range(1, 31):
        loss, nus, vs, m_nu, u_nu, m_v, u_v = step(
            x, y, n1, n2, state, nus, vs, m_nu, u_nu, m_v, u_v,
            jnp.float32(1e-2), jnp.float32(t), qmax, A16)
        losses.append(float(loss))
    assert losses[-1] < 0.5 * losses[0], losses[::10]


def test_lwc_step_reduces_reconstruction_loss():
    p = init_params(CFG)
    w, n1, n2 = block_slice(p, 0)
    qmax = jnp.float32(3.0)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(4, CFG.max_seq, CFG.d_model)),
                    jnp.float32)
    y = M.block_fp_fwd(x, n1, n2, w, CFG, A16)
    gam, bet = [], []
    for name in LINEAR_NAMES:
        o, i = w[name].shape
        g = min(32, i)
        gam.append(jnp.full((o, i // g), 4.0, jnp.float32))
        bet.append(jnp.full((o, i // g), 4.0, jnp.float32))
    zeros = lambda ls: [jnp.zeros_like(a) for a in ls]
    m_g, u_g, m_b, u_b = zeros(gam), zeros(gam), zeros(bet), zeros(bet)
    step = jax.jit(lambda *a: M.lwc_step(*a, cfg=CFG))
    losses = []
    for t in range(1, 26):
        loss, gam, bet, m_g, u_g, m_b, u_b = step(
            x, y, n1, n2, w, gam, bet, m_g, u_g, m_b, u_b,
            jnp.float32(5e-2), jnp.float32(t), qmax, A16)
        losses.append(float(loss))
    assert losses[-1] < 0.9 * losses[0], losses[::8]


def test_train_step_reduces_lm_loss():
    cfg = CFG
    p = init_params(cfg)
    zeros = {k: jnp.zeros_like(v) for k, v in p.items()}
    m, u = dict(zeros), dict(zeros)
    rng = np.random.default_rng(5)
    # strongly structured tokens so a few steps make progress
    base = np.arange(cfg.max_seq) % 8
    toks = jnp.asarray(np.stack([np.roll(base, i) for i in range(8)]),
                       jnp.int32)
    step = jax.jit(lambda *a: M.train_step(*a, cfg=cfg))
    losses = []
    for t in range(1, 21):
        loss, p, m, u = step(toks, p, m, u, jnp.float32(3e-3),
                             jnp.float32(t))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses[::5]


def test_act_quant_degrades_gracefully():
    """A8 ~ FP; A3 visibly noisier — ordering must hold at model level."""
    p = init_params(CFG)
    tokens = jnp.asarray(np.random.default_rng(0).integers(
        0, CFG.vocab_size, size=(2, CFG.max_seq)), jnp.int32)
    nll16 = float(jnp.mean(M.model_nll(tokens, p, CFG, A16)))
    nll8 = float(jnp.mean(M.model_nll(tokens, p, CFG, jnp.float32(255.0))))
    nll3 = float(jnp.mean(M.model_nll(tokens, p, CFG, jnp.float32(7.0))))
    assert abs(nll8 - nll16) < 0.1
    assert abs(nll3 - nll16) > abs(nll8 - nll16) - 1e-6
