"""Unit tests for the differentiable quantization math (quantize.py).

These semantics are mirrored bit-for-bit by rust/src/quant/; invariants
proven here are re-proven on the Rust side with proptest.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import quantize as Q


def mk_weight(o=16, i=32, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(o, i)).astype(np.float32))


def mk_state(w, g, qmax, gamma=1.0, beta=1.0):
    o, i = w.shape
    wg = w.reshape(o, i // g, g)
    s, z = Q.minmax_scale(wg, gamma, beta, qmax)
    wf = Q.w_floor_init(w, s)
    nu = Q.nu_init(w, s, z, qmax)
    v = jnp.zeros_like(s)
    return wf, s, z, nu, v


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
@pytest.mark.parametrize("g", [8, 16, 32])
def test_soft_qdq_init_is_identity_inside_range(bits, g):
    """At init (nu from frac, v=0) the soft qdq reproduces W up to clamp."""
    qmax = float(2 ** bits - 1)
    w = mk_weight()
    wf, s, z, nu, v = mk_state(w, g, qmax)
    what = Q.soft_qdq(wf, s, z, nu, v, qmax)
    # Interior points (not clamped) reconstruct to ~1e-3 * s; boundary
    # points may clip by up to one step.
    err = jnp.abs(what - w)
    smax = float(jnp.max(s))
    assert float(jnp.median(err)) < 1e-3 * smax + 1e-6
    assert float(jnp.max(err)) < 1.5 * smax


@pytest.mark.parametrize("bits", [2, 4])
def test_hard_qdq_on_integer_grid(bits):
    """hard_qdq output lies exactly on the dequantization grid."""
    qmax = float(2 ** bits - 1)
    w = mk_weight()
    wf, s, z, nu, v = mk_state(w, 16, qmax)
    what = Q.hard_qdq(wf, s, z, nu, v, qmax)
    o, i = w.shape
    g = 16
    sg = jnp.repeat(s, g, axis=1)
    zg = jnp.repeat(z, g, axis=1)
    codes = what / (2.0 * jax.nn.sigmoid(jnp.repeat(v, g, axis=1))) / sg + zg
    assert float(jnp.max(jnp.abs(codes - jnp.round(codes)))) < 1e-3
    assert float(jnp.min(codes)) >= -1e-3
    assert float(jnp.max(codes)) <= qmax + 1e-3


def test_rtn_error_bound():
    """RTN error is bounded by s/2 inside the clip range."""
    qmax = 15.0
    w = mk_weight()
    o, i = w.shape
    g = 16
    wg = w.reshape(o, i // g, g)
    s, z = Q.minmax_scale(wg, 1.0, 1.0, qmax)
    what = Q.rtn_qdq(w, s, z, qmax)
    err = jnp.abs(what - w).reshape(o, i // g, g)
    assert bool(jnp.all(err <= 0.75 * s[..., None] + 1e-6))


def test_hard_matches_soft_when_saturated():
    """Saturating nu at +-SAT_NU makes soft == hard exactly."""
    qmax = 3.0
    w = mk_weight()
    wf, s, z, nu, v = mk_state(w, 16, qmax)
    nu_sat = jnp.where(nu > 0, Q.SAT_NU, -Q.SAT_NU)
    soft = Q.soft_qdq(wf, s, z, nu_sat, v, qmax)
    hard = Q.hard_qdq(wf, s, z, nu_sat, v, qmax)
    np.testing.assert_allclose(np.asarray(soft), np.asarray(hard),
                               rtol=0, atol=1e-6)


def test_saturated_nu_has_zero_gradient():
    """The paper's masking trick: hardened (saturated) logits get grad 0."""
    qmax = 3.0
    w = mk_weight()
    wf, s, z, nu, v = mk_state(w, 16, qmax)
    nu = nu.at[0].set(Q.SAT_NU).at[1].set(-Q.SAT_NU)

    def loss(nu_):
        return jnp.sum(Q.soft_qdq(wf, s, z, nu_, v, qmax) ** 2)

    g = jax.grad(loss)(nu)
    assert float(jnp.max(jnp.abs(g[0]))) == 0.0
    assert float(jnp.max(jnp.abs(g[1]))) == 0.0, \
        "sigmoid must saturate exactly at -SAT_NU"
    assert float(jnp.max(jnp.abs(g[2:]))) > 0.0


def test_dst_scale_range():
    """DST factor 2*sigmoid(v) stays in (0, 2) and is 1 at v=0."""
    qmax = 3.0
    w = mk_weight()
    wf, s, z, nu, v = mk_state(w, 16, qmax)
    base = Q.soft_qdq(wf, s, z, nu, v, qmax)
    big = Q.soft_qdq(wf, s, z, nu, v + 100.0, qmax)
    np.testing.assert_allclose(np.asarray(big), np.asarray(2.0 * base),
                               rtol=1e-5, atol=1e-6)


def test_lwc_qdq_grad_flows_and_shrinks_scale():
    """LWC clip logits receive gradient through the STE."""
    qmax = 3.0
    w = mk_weight()
    o, i = w.shape
    gr = jnp.zeros((o, i // 16), jnp.float32) + 4.0  # sigmoid ~ 0.98
    br = jnp.zeros_like(gr) + 4.0

    def loss(gr_, br_):
        return jnp.mean((Q.lwc_qdq(w, gr_, br_, qmax) - w) ** 2)

    g1, g2 = jax.grad(loss, argnums=(0, 1))(gr, br)
    assert float(jnp.max(jnp.abs(g1))) > 0.0
    assert float(jnp.max(jnp.abs(g2))) > 0.0


@pytest.mark.parametrize("qmax,expect_quant", [(3.0, True), (65535.0, False)])
def test_act_fakequant_sentinel(qmax, expect_quant):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    xq = Q.act_fakequant(x, jnp.float32(qmax))
    if expect_quant:
        assert float(jnp.max(jnp.abs(xq - x))) > 1e-4
        # per-token: each row has at most qmax+1 distinct values
        for r in np.asarray(xq):
            assert len(np.unique(r)) <= int(qmax) + 1
    else:
        np.testing.assert_array_equal(np.asarray(xq), np.asarray(x))


def test_act_fakequant_error_shrinks_with_bits():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    errs = []
    for bits in (3, 4, 8):
        xq = Q.act_fakequant(x, jnp.float32(2 ** bits - 1))
        errs.append(float(jnp.mean((xq - x) ** 2)))
    assert errs[0] > errs[1] > errs[2]


def test_nu_init_round_trip_vs_floor():
    """sigmoid(nu_init) == frac(W/s) away from the clip boundary."""
    qmax = 15.0
    w = mk_weight()
    wf, s, z, nu, v = mk_state(w, 16, qmax)
    o, i = w.shape
    sg = jnp.repeat(s, 16, axis=1)
    frac = w / sg - jnp.floor(w / sg)
    interior = (frac > 1e-3) & (frac < 1 - 1e-3)
    got = jax.nn.sigmoid(nu)
    np.testing.assert_allclose(np.asarray(got[interior]),
                               np.asarray(frac[interior]), atol=1e-4)
